//! Fast Walsh–Hadamard transform — the building block of QuaRot-style
//! rotations. H_n is orthogonal (up to 1/√n normalization), so applying
//! it to both activation channels and weight columns leaves X·Wᵀ
//! invariant while spreading outlier energy across channels.

/// In-place normalized fast Walsh–Hadamard transform of a power-of-two
/// length slice: x ← H·x/√n. O(n log n).
pub fn fwht_normalized(x: &mut [f32]) {
    let n = x.len();
    assert!(n.is_power_of_two(), "FWHT length must be a power of two");
    let mut h = 1;
    while h < n {
        let mut i = 0;
        while i < n {
            for j in i..i + h {
                let a = x[j];
                let b = x[j + h];
                x[j] = a + b;
                x[j + h] = a - b;
            }
            i += h * 2;
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f32).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Largest power of two ≤ n.
pub fn pow2_floor(n: usize) -> usize {
    if n == 0 {
        0
    } else {
        1 << (usize::BITS - 1 - n.leading_zeros())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    #[test]
    fn involution_up_to_normalization() {
        // H/√n applied twice is the identity.
        let mut rng = Prng::new(70);
        for n in [2usize, 8, 64, 256] {
            let orig: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut x = orig.clone();
            fwht_normalized(&mut x);
            fwht_normalized(&mut x);
            for (a, b) in x.iter().zip(&orig) {
                assert!((a - b).abs() < 1e-4, "n={n}");
            }
        }
    }

    #[test]
    fn preserves_l2_norm() {
        let mut rng = Prng::new(71);
        let orig: Vec<f32> = (0..128).map(|_| rng.normal()).collect();
        let mut x = orig.clone();
        fwht_normalized(&mut x);
        let n0: f32 = orig.iter().map(|v| v * v).sum();
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-3 * n0);
    }

    #[test]
    fn spreads_a_spike() {
        // The Figure-2 phenomenon: a single outlier's magnitude is spread
        // to every channel (each gets ±spike/√n).
        let n = 64;
        let mut x = vec![0.0f32; n];
        x[7] = 8.0;
        fwht_normalized(&mut x);
        for &v in &x {
            assert!((v.abs() - 1.0).abs() < 1e-5); // 8/√64 = 1
        }
    }

    #[test]
    fn small_cases_exact() {
        let mut x = vec![1.0f32, 1.0];
        fwht_normalized(&mut x);
        let s = 2f32.sqrt();
        assert!((x[0] - s).abs() < 1e-6 && x[1].abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_pow2_rejected() {
        let mut x = vec![0.0f32; 3];
        fwht_normalized(&mut x);
    }

    #[test]
    fn pow2_floor_cases() {
        assert_eq!(pow2_floor(1), 1);
        assert_eq!(pow2_floor(16), 16);
        assert_eq!(pow2_floor(17), 16);
        assert_eq!(pow2_floor(4095), 2048);
        assert_eq!(pow2_floor(0), 0);
    }
}
