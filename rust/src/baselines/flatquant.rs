//! FlatQuant-lite baseline (Sun et al., 2024, simplified).
//!
//! FlatQuant learns per-layer affine transformations that flatten
//! activation/weight distributions before quantization. The official
//! method trains Kronecker-factored matrices with gradients; our
//! substitution (documented in DESIGN.md) keeps the same *objective* —
//! flatness of the per-channel range profile — but solves the diagonal
//! case in closed form: the scale s_j = √(amax_X(j)/amax_W(j)) that
//! equalizes activation and weight ranges per channel (this is the
//! optimum of the per-channel min-max product objective, and is also
//! SmoothQuant's α=0.5 point), composed with a *range-balancing* second
//! pass that iteratively re-centers group ranges. The learned-rotation
//! part is intentionally omitted: rotations are exactly what the paper
//! shows to be counterproductive on NVFP4, and Table 1 treats FlatQuant
//! as a strong-but-beatable W4A4 baseline, which this lite version is.

use crate::formats::{Format, RowQuantizer};
use crate::tensor::Mat;

/// Number of balancing refinement sweeps.
const SWEEPS: usize = 3;

/// Offline preparation: returns (quantized transformed weight, online
/// per-channel activation multiplier).
pub fn prepare(w: &Mat, act_absmax: &[f32], fmt: Format) -> (Mat, Vec<f32>) {
    assert_eq!(w.cols, act_absmax.len());
    let k = w.cols;
    let mut w_absmax = vec![0.0f32; k];
    for r in 0..w.rows {
        for (c, &v) in w.row(r).iter().enumerate() {
            w_absmax[c] = w_absmax[c].max(v.abs());
        }
    }
    // Closed-form flattening point (geometric mean balance).
    let mut s = vec![1.0f32; k];
    for j in 0..k {
        let a = act_absmax[j].max(1e-8);
        let ww = w_absmax[j].max(1e-8);
        s[j] = (a / ww).sqrt().clamp(1e-4, 1e4);
    }
    // Refinement sweeps: push per-channel transformed ranges toward the
    // group median (flatness in the block-quantization sense).
    let g = fmt.group();
    for _ in 0..SWEEPS {
        let ranges: Vec<f32> = (0..k)
            .map(|j| (act_absmax[j].max(1e-8) / s[j]).max(w_absmax[j] * s[j]))
            .collect();
        for blk in 0..k.div_ceil(g) {
            let lo = blk * g;
            let hi = ((blk + 1) * g).min(k);
            let mut sorted: Vec<f32> = ranges[lo..hi].to_vec();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = sorted[sorted.len() / 2].max(1e-8);
            for j in lo..hi {
                // Move channel j's activation range toward the block
                // median: scale the divisor by sqrt(range_j / median).
                let adj = (ranges[j] / med).sqrt().clamp(0.5, 2.0);
                s[j] = (s[j] * adj.sqrt()).clamp(1e-4, 1e4);
            }
        }
    }
    let mut wm = w.clone();
    wm.scale_cols(&s);
    let wq = RowQuantizer::new(fmt).qdq_mat(&wm);
    let inv_s: Vec<f32> = s.iter().map(|&v| 1.0 / v).collect();
    (wq, inv_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_nt;
    use crate::util::{stats, Prng};

    fn workload(seed: u64) -> (Mat, Mat) {
        let mut rng = Prng::new(seed);
        let x = Mat::from_fn(16, 128, |_, c| {
            let v = rng.normal();
            if c % 21 == 4 {
                v * 35.0
            } else {
                v
            }
        });
        let mut w = Mat::zeros(16, 128);
        w.fill_random_normal(&mut rng, 0.4);
        (x, w)
    }

    #[test]
    fn transform_preserves_product_unquantized() {
        let (x, w) = workload(110);
        let (_, inv_s) = prepare(&w, &x.col_absmax(), Format::Nvfp4);
        let s: Vec<f32> = inv_s.iter().map(|v| 1.0 / v).collect();
        let mut xs = x.clone();
        xs.scale_cols(&inv_s);
        let mut wm = w.clone();
        wm.scale_cols(&s);
        let y0 = matmul_nt(&x, &w);
        let y1 = matmul_nt(&xs, &wm);
        for (a, b) in y0.data.iter().zip(&y1.data) {
            assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()));
        }
    }

    #[test]
    fn flattens_activation_profile() {
        let (x, w) = workload(111);
        let (_, inv_s) = prepare(&w, &x.col_absmax(), Format::Nvfp4);
        let mut xs = x.clone();
        xs.scale_cols(&inv_s);
        // Ratio of max channel range to median channel range shrinks.
        let profile = |m: &Mat| {
            let am = m.col_absmax();
            let mut sorted = am.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = sorted[sorted.len() / 2].max(1e-8);
            am.iter().fold(0.0f32, |mm, &v| mm.max(v)) / med
        };
        assert!(profile(&xs) < profile(&x) * 0.5);
    }

    #[test]
    fn improves_over_rtn_at_4bit() {
        let (x, w) = workload(112);
        let y_ref = matmul_nt(&x, &w);
        let q = RowQuantizer::new(Format::Nvfp4);
        let rtn = matmul_nt(&q.qdq_mat(&x), &q.qdq_mat(&w));
        let (wq, inv_s) = prepare(&w, &x.col_absmax(), Format::Nvfp4);
        let mut xs = x.clone();
        xs.scale_cols(&inv_s);
        let flat = matmul_nt(&q.qdq_mat(&xs), &wq);
        let e_rtn = stats::mse(&rtn.data, &y_ref.data);
        let e_flat = stats::mse(&flat.data, &y_ref.data);
        assert!(e_flat < e_rtn * 1.5, "flat {e_flat} vs rtn {e_rtn}");
    }

    #[test]
    fn degenerate_inputs_stay_finite() {
        let w = Mat::zeros(4, 32);
        let (wq, inv_s) = prepare(&w, &vec![0.0; 32], Format::Nvfp4);
        assert!(wq.data.iter().all(|v| v.is_finite()));
        assert!(inv_s.iter().all(|v| v.is_finite() && *v > 0.0));
    }
}
