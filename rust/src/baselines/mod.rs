//! Baseline PTQ methods the paper compares against (§2, §4.1).
//!
//! All baselines are implemented from scratch against the same
//! [`crate::formats`] codecs so comparisons are apples-to-apples:
//!
//! * **RTN** — plain round-to-nearest block quantization (the lower bound).
//! * **SmoothQuant** ([`smoothquant`]) — per-channel difficulty migration
//!   X·diag(s)⁻¹, diag(s)·W with s = amax_X^α / amax_W^(1−α).
//! * **QuaRot** ([`quarot`]) — random Hadamard rotations of the channel
//!   dimension; flattens outliers globally but (the paper's Figure 2
//!   argument) inflates local block ranges.
//! * **Atom** ([`atom`]) — reorder + mixed precision: INT8/FP16-class
//!   treatment of outlier channels, INT4 bulk.
//! * **FlatQuant-lite** ([`flatquant`]) — calibrated per-channel affine
//!   flattening (a learnable-transform stand-in: closed-form power
//!   iteration instead of gradient training, same flattening objective).
//! * **W4A8** — 4-bit weights (MXFP4) with 8-bit activations (MXFP8), the
//!   accuracy ceiling ARCQuant aims to reach within W4A4.
//!
//! Each method is a [`Method`] variant prepared into a [`PreparedLinear`]
//! (`prepare`/`forward`), so the eval harness and report generators
//! treat them uniformly.

pub mod atom;
pub mod flatquant;
pub mod hadamard;
pub mod quarot;
pub mod smoothquant;

use crate::formats::{Format, RowQuantizer};
use crate::quant::{ArcQuantLinear, LayerPlan, PackedArcLinear};
use crate::tensor::{matmul_nt, Mat};

/// How a prepared layer executes its GEMM.
///
/// * [`ExecPath::Qdq`] — fused quantize-dequantize simulation: operands
///   are f32 values on the quantization grid, the GEMM is the f32
///   [`matmul_nt`]. Numerically authoritative, memory-hungry.
/// * [`ExecPath::Packed`] — real packed codes end-to-end through
///   [`crate::tensor::matmul_nt_packed`]: weights live as 4-bit codes +
///   block scales (~1/7.5 of f32), activations are quantized straight to
///   codes. Methods without a packed implementation, and layer shapes that
///   are not group-aligned, silently fall back to QDQ.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum ExecPath {
    #[default]
    Qdq,
    Packed,
}

/// Every quantization strategy the experiments sweep.
#[derive(Clone, Debug, PartialEq)]
pub enum Method {
    /// Full-precision reference (no quantization).
    Fp16,
    /// Plain RTN in the given format (W4A4 when fmt is 4-bit).
    Rtn { fmt: Format },
    /// W4A8: MXFP4 weights + MXFP8 activations, RTN.
    W4A8Rtn,
    /// SmoothQuant migration then RTN in `fmt`.
    Smooth { fmt: Format, alpha: f32 },
    /// QuaRot random-Hadamard rotation then RTN in `fmt`.
    QuaRot { fmt: Format, seed: u64 },
    /// Atom-style mixed precision (outliers INT8, bulk INT4-g128).
    Atom { outlier_channels: usize },
    /// FlatQuant-lite affine flattening then RTN in `fmt`.
    FlatQuant { fmt: Format },
    /// ARCQuant augmented residual channels in `fmt`.
    ArcQuant { fmt: Format, max_s: Option<usize> },
}

impl Method {
    pub fn name(&self) -> String {
        match self {
            Method::Fp16 => "FP16".into(),
            Method::Rtn { fmt } => format!("{} + RTN", fmt.name()),
            Method::W4A8Rtn => "W4A8 + RTN".into(),
            Method::Smooth { fmt, .. } => format!("{} + Smooth", fmt.name()),
            Method::QuaRot { fmt, .. } => format!("{} + QuaRot", fmt.name()),
            Method::Atom { .. } => "Atom".into(),
            Method::FlatQuant { .. } => "FlatQuant".into(),
            Method::ArcQuant { .. } => "ARCQuant".into(),
        }
    }
}

/// A prepared (weights processed offline) linear layer under some method.
/// `forward` runs the online path: activation transform + quantization +
/// GEMM, exactly what the serving engine executes per layer.
pub enum PreparedLinear {
    Fp16 {
        w: Mat,
    },
    /// Both operands fake-quantized independently (RTN / W4A8):
    Rtn {
        wq: Mat,
        a_fmt: Format,
        w_fmt: Format,
    },
    /// SmoothQuant: activation divided by `s`, weight pre-multiplied.
    Smooth {
        wq: Mat,
        inv_s: Vec<f32>,
        fmt: Format,
    },
    /// QuaRot: activations rotated online; weights pre-rotated offline.
    QuaRot {
        wq: Mat,
        rot: quarot::BlockRotation,
        fmt: Format,
    },
    /// Atom mixed precision.
    Atom(atom::AtomLinear),
    /// FlatQuant-lite.
    Flat {
        wq: Mat,
        inv_s: Vec<f32>,
        fmt: Format,
    },
    /// ARCQuant.
    Arc(ArcQuantLinear),
    /// ARCQuant (or RTN, S=0) on the packed-execution path: codes
    /// end-to-end.
    PackedArc(PackedArcLinear),
}

impl PreparedLinear {
    /// Like [`Self::prepare`], with an explicit execution path. Packed
    /// execution is implemented for the methods whose online transform is
    /// "quantize the activation" (ARCQuant, RTN); everything else — and
    /// any layer whose K/S aren't group-aligned — falls back to QDQ.
    pub fn prepare_with(
        method: &Method,
        w: &Mat,
        calib: &LayerCalib,
        exec: ExecPath,
    ) -> PreparedLinear {
        if exec == ExecPath::Packed {
            if let Some(plan) = Self::quantize_only_plan(method, w, calib) {
                if let Ok(p) = PackedArcLinear::prepare(w, plan) {
                    return PreparedLinear::PackedArc(p);
                }
            }
        }
        Self::prepare(method, w, calib)
    }

    /// The [`LayerPlan`] for methods whose online transform is purely
    /// "quantize the activation" (ARCQuant, RTN) — the methods the packed
    /// path can execute. Single source of truth shared with
    /// [`Self::prepare`]'s ArcQuant branch.
    fn quantize_only_plan(method: &Method, w: &Mat, calib: &LayerCalib) -> Option<LayerPlan> {
        match method {
            Method::ArcQuant { fmt, max_s } => Some(match max_s {
                Some(cap) => {
                    LayerPlan::from_calibration_capped(&calib.col_absmax, *fmt, *cap)
                }
                None => LayerPlan::from_calibration(&calib.col_absmax, *fmt),
            }),
            Method::Rtn { fmt } => Some(LayerPlan::rtn(w.cols, *fmt)),
            _ => None,
        }
    }

    /// Offline preparation given the layer weight [M, K] and calibration
    /// statistics for this layer's input activations.
    pub fn prepare(method: &Method, w: &Mat, calib: &LayerCalib) -> PreparedLinear {
        match method {
            Method::Fp16 => PreparedLinear::Fp16 { w: w.clone() },
            Method::Rtn { fmt } => PreparedLinear::Rtn {
                wq: RowQuantizer::new(*fmt).qdq_mat(w),
                a_fmt: *fmt,
                w_fmt: *fmt,
            },
            Method::W4A8Rtn => PreparedLinear::Rtn {
                wq: RowQuantizer::new(Format::Mxfp4).qdq_mat(w),
                a_fmt: Format::Mxfp8E4M3,
                w_fmt: Format::Mxfp4,
            },
            Method::Smooth { fmt, alpha } => {
                let (wq, inv_s) = smoothquant::prepare(w, &calib.col_absmax, *alpha, *fmt);
                PreparedLinear::Smooth { wq, inv_s, fmt: *fmt }
            }
            Method::QuaRot { fmt, seed } => {
                let rot = quarot::BlockRotation::new(w.cols, *seed);
                let wr = rot.apply_cols(w);
                PreparedLinear::QuaRot {
                    wq: RowQuantizer::new(*fmt).qdq_mat(&wr),
                    rot,
                    fmt: *fmt,
                }
            }
            Method::Atom { outlier_channels } => {
                PreparedLinear::Atom(atom::AtomLinear::prepare(w, calib, *outlier_channels))
            }
            Method::FlatQuant { fmt } => {
                let (wq, inv_s) = flatquant::prepare(w, &calib.col_absmax, *fmt);
                PreparedLinear::Flat { wq, inv_s, fmt: *fmt }
            }
            Method::ArcQuant { .. } => {
                let plan = Self::quantize_only_plan(method, w, calib)
                    .expect("ArcQuant always has a plan");
                PreparedLinear::Arc(ArcQuantLinear::prepare(w, plan))
            }
        }
    }

    /// Online forward pass Y = Q(f(X)) · Q(W')ᵀ.
    pub fn forward(&self, x: &Mat) -> Mat {
        match self {
            PreparedLinear::Fp16 { w } => matmul_nt(x, w),
            PreparedLinear::Rtn { wq, a_fmt, .. } => {
                let xq = RowQuantizer::new(*a_fmt).qdq_mat(x);
                matmul_nt(&xq, wq)
            }
            PreparedLinear::Smooth { wq, inv_s, fmt } => {
                let mut xs = x.clone();
                xs.scale_cols(inv_s);
                let xq = RowQuantizer::new(*fmt).qdq_mat(&xs);
                matmul_nt(&xq, wq)
            }
            PreparedLinear::QuaRot { wq, rot, fmt } => {
                let xr = rot.apply_cols(x);
                let xq = RowQuantizer::new(*fmt).qdq_mat(&xr);
                matmul_nt(&xq, wq)
            }
            PreparedLinear::Atom(a) => a.forward(x),
            PreparedLinear::Flat { wq, inv_s, fmt } => {
                let mut xs = x.clone();
                xs.scale_cols(inv_s);
                let xq = RowQuantizer::new(*fmt).qdq_mat(&xs);
                matmul_nt(&xq, wq)
            }
            PreparedLinear::Arc(a) => a.forward(x),
            PreparedLinear::PackedArc(a) => a.forward(x),
        }
    }

    /// Row-wise (per-token) forward: bit-identical to calling
    /// [`Self::forward`] on each row of `x` as its own [1, K] matrix, but
    /// still one batched GEMM per call for every method with a batched
    /// implementation. This is the decode-batch entry point: per-sequence
    /// `decode_step` runs `forward` on [1, K] activations, so a batched
    /// decode that uses `forward_rowwise` reproduces it exactly (the
    /// NVFP4 tensor scale is the only whole-matrix statistic in the online
    /// path, and the row-wise quantizers pin it per row).
    pub fn forward_rowwise(&self, x: &Mat) -> Mat {
        match self {
            PreparedLinear::Fp16 { w } => matmul_nt(x, w),
            PreparedLinear::Rtn { wq, a_fmt, .. } => {
                let xq = RowQuantizer::new(*a_fmt).qdq_mat_rowwise(x);
                matmul_nt(&xq, wq)
            }
            PreparedLinear::Smooth { wq, inv_s, fmt } => {
                let mut xs = x.clone();
                xs.scale_cols(inv_s);
                let xq = RowQuantizer::new(*fmt).qdq_mat_rowwise(&xs);
                matmul_nt(&xq, wq)
            }
            PreparedLinear::QuaRot { wq, rot, fmt } => {
                let xr = rot.apply_cols(x);
                let xq = RowQuantizer::new(*fmt).qdq_mat_rowwise(&xr);
                matmul_nt(&xq, wq)
            }
            // Atom has no batched per-row implementation; B single-row
            // forwards are the definition of row-wise semantics, so this
            // stays exact (Atom is not on the serving decode path).
            PreparedLinear::Atom(a) => {
                let mut out = Mat::zeros(x.rows, self.out_dim());
                for r in 0..x.rows {
                    let single = Mat::from_vec(1, x.cols, x.row(r).to_vec());
                    let y = a.forward(&single);
                    out.row_mut(r).copy_from_slice(y.row(0));
                }
                out
            }
            PreparedLinear::Flat { wq, inv_s, fmt } => {
                let mut xs = x.clone();
                xs.scale_cols(inv_s);
                let xq = RowQuantizer::new(*fmt).qdq_mat_rowwise(&xs);
                matmul_nt(&xq, wq)
            }
            PreparedLinear::Arc(a) => a.forward_rowwise(x),
            PreparedLinear::PackedArc(a) => a.forward_rowwise(x),
        }
    }

    /// Output dimension M of the prepared layer.
    pub fn out_dim(&self) -> usize {
        match self {
            PreparedLinear::Fp16 { w } => w.rows,
            PreparedLinear::Rtn { wq, .. }
            | PreparedLinear::Smooth { wq, .. }
            | PreparedLinear::QuaRot { wq, .. }
            | PreparedLinear::Flat { wq, .. } => wq.rows,
            PreparedLinear::Atom(a) => a.out_dim(),
            PreparedLinear::Arc(a) => a.out_dim,
            PreparedLinear::PackedArc(a) => a.out_dim,
        }
    }

    /// S (augmented channels) if the method has one.
    pub fn s(&self) -> usize {
        match self {
            PreparedLinear::Arc(a) => a.s(),
            PreparedLinear::PackedArc(a) => a.s(),
            PreparedLinear::Atom(a) => a.outliers(),
            _ => 0,
        }
    }

    /// Which execution path this prepared layer actually runs (Packed
    /// requests can fall back to Qdq on unpackable shapes).
    pub fn exec_path(&self) -> ExecPath {
        match self {
            PreparedLinear::PackedArc(_) => ExecPath::Packed,
            _ => ExecPath::Qdq,
        }
    }

    /// Real packed weight bytes when this layer stores codes; `None` for
    /// the QDQ simulation (which stores f32 and is accounted by format
    /// arithmetic instead).
    pub fn packed_weight_bytes(&self) -> Option<u64> {
        match self {
            PreparedLinear::PackedArc(a) => Some(a.weight_bytes()),
            _ => None,
        }
    }
}

/// Calibration statistics for one linear layer's input.
#[derive(Clone, Debug, Default)]
pub struct LayerCalib {
    /// Per-channel absolute maxima of the input activations.
    pub col_absmax: Vec<f32>,
    /// One retained activation batch (first seen) — used by the error
    /// analyses behind Figures 2/3; not required for quantization.
    pub sample: Option<Mat>,
}

impl LayerCalib {
    pub fn from_activations(x: &Mat) -> LayerCalib {
        LayerCalib {
            col_absmax: x.col_absmax(),
            sample: Some(x.clone()),
        }
    }

    /// Merge statistics from another batch (element-wise max; the first
    /// sample is retained).
    pub fn merge(&mut self, other: &LayerCalib) {
        if self.col_absmax.is_empty() {
            self.col_absmax = other.col_absmax.clone();
            self.sample = other.sample.clone();
            return;
        }
        assert_eq!(self.col_absmax.len(), other.col_absmax.len());
        for (a, b) in self.col_absmax.iter_mut().zip(&other.col_absmax) {
            *a = a.max(*b);
        }
        if self.sample.is_none() {
            self.sample = other.sample.clone();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, Prng};

    fn workload(seed: u64) -> (Mat, Mat, LayerCalib) {
        let mut rng = Prng::new(seed);
        let x = Mat::from_fn(16, 256, |_, c| {
            let v = rng.normal();
            if c % 31 == 4 {
                v * 40.0
            } else {
                v
            }
        });
        let mut w = Mat::zeros(32, 256);
        w.fill_random_normal(&mut rng, 0.3);
        let calib = LayerCalib::from_activations(&x);
        (x, w, calib)
    }

    fn method_mse(method: &Method, seed: u64) -> f64 {
        let (x, w, calib) = workload(seed);
        let y_ref = matmul_nt(&x, &w);
        let lin = PreparedLinear::prepare(method, &w, &calib);
        let y = lin.forward(&x);
        stats::mse(&y.data, &y_ref.data)
    }

    #[test]
    fn fp16_is_exact() {
        assert_eq!(method_mse(&Method::Fp16, 60), 0.0);
    }

    #[test]
    fn paper_ordering_on_nvfp4() {
        // Table 2's qualitative ordering on outlier-heavy activations:
        // ARCQuant < {Smooth, RTN, QuaRot} reconstruction error.
        let arc = method_mse(
            &Method::ArcQuant { fmt: Format::Nvfp4, max_s: None },
            61,
        );
        let rtn = method_mse(&Method::Rtn { fmt: Format::Nvfp4 }, 61);
        let smooth = method_mse(
            &Method::Smooth { fmt: Format::Nvfp4, alpha: 0.5 },
            61,
        );
        let quarot = method_mse(
            &Method::QuaRot { fmt: Format::Nvfp4, seed: 0 },
            61,
        );
        assert!(arc < rtn, "arc {arc} !< rtn {rtn}");
        assert!(arc < smooth, "arc {arc} !< smooth {smooth}");
        assert!(arc < quarot, "arc {arc} !< quarot {quarot}");
    }

    #[test]
    fn arcquant_reaches_w4a8_class_error() {
        // The headline: ARCQuant (W4A4) ≈ W4A8 RTN accuracy.
        let arc = method_mse(
            &Method::ArcQuant { fmt: Format::Nvfp4, max_s: None },
            62,
        );
        let w4a8 = method_mse(&Method::W4A8Rtn, 62);
        assert!(
            arc <= w4a8 * 2.0,
            "ARCQuant {arc} should be within 2x of W4A8 {w4a8}"
        );
    }

    #[test]
    fn packed_exec_path_matches_qdq_and_shrinks_weights() {
        let (x, w, calib) = workload(63);
        for method in [
            Method::ArcQuant { fmt: Format::Nvfp4, max_s: None },
            Method::Rtn { fmt: Format::Nvfp4 },
        ] {
            let qdq = PreparedLinear::prepare_with(&method, &w, &calib, ExecPath::Qdq);
            let packed =
                PreparedLinear::prepare_with(&method, &w, &calib, ExecPath::Packed);
            assert_eq!(qdq.exec_path(), ExecPath::Qdq);
            assert_eq!(packed.exec_path(), ExecPath::Packed, "{method:?}");
            assert_eq!(qdq.s(), packed.s());
            let (a, b) = (qdq.forward(&x), packed.forward(&x));
            let rel = stats::rel_frob_err(&b.data, &a.data);
            assert!(rel < 1e-5, "{method:?}: packed vs qdq rel err {rel}");
            // real codes: ≥6x smaller than the f32 simulation of the same
            // augmented matrix
            let bytes = packed.packed_weight_bytes().unwrap();
            let f32_bytes = (w.rows * (w.cols + packed.s()) * 4) as u64;
            assert!(bytes * 6 <= f32_bytes, "{bytes} vs {f32_bytes}");
        }
    }

    #[test]
    fn packed_request_falls_back_for_unpackable() {
        let (x, w, calib) = workload(64);
        // SmoothQuant has no packed implementation → QDQ fallback.
        let method = Method::Smooth { fmt: Format::Nvfp4, alpha: 0.5 };
        let lin = PreparedLinear::prepare_with(&method, &w, &calib, ExecPath::Packed);
        assert_eq!(lin.exec_path(), ExecPath::Qdq);
        assert!(lin.packed_weight_bytes().is_none());
        assert!(lin.forward(&x).data.iter().all(|v| v.is_finite()));

        // Unaligned K → fallback even for ARCQuant.
        let mut rng = crate::util::Prng::new(99);
        let mut w2 = Mat::zeros(8, 40);
        w2.fill_random_normal(&mut rng, 0.5);
        let calib2 = LayerCalib {
            col_absmax: vec![1.0; 40],
            sample: None,
        };
        let lin2 = PreparedLinear::prepare_with(
            &Method::ArcQuant { fmt: Format::Nvfp4, max_s: None },
            &w2,
            &calib2,
            ExecPath::Packed,
        );
        assert_eq!(lin2.exec_path(), ExecPath::Qdq);
    }

    #[test]
    fn forward_rowwise_matches_per_row_forward_every_method() {
        // The decode-batch contract at the PreparedLinear layer, for every
        // method and both exec paths: forward_rowwise([B, K]) row r is
        // bit-identical to forward on row r alone.
        let (x, w, calib) = workload(65);
        let methods = [
            Method::Fp16,
            Method::Rtn { fmt: Format::Nvfp4 },
            Method::W4A8Rtn,
            Method::Smooth { fmt: Format::Nvfp4, alpha: 0.5 },
            Method::QuaRot { fmt: Format::Nvfp4, seed: 0 },
            Method::Atom { outlier_channels: 64 },
            Method::FlatQuant { fmt: Format::Nvfp4 },
            Method::ArcQuant { fmt: Format::Nvfp4, max_s: None },
        ];
        for method in &methods {
            for exec in [ExecPath::Qdq, ExecPath::Packed] {
                let lin = PreparedLinear::prepare_with(method, &w, &calib, exec);
                assert_eq!(lin.out_dim(), w.rows, "{method:?}");
                let batched = lin.forward_rowwise(&x);
                for r in 0..x.rows {
                    let single = Mat::from_vec(1, x.cols, x.row(r).to_vec());
                    let want = lin.forward(&single);
                    assert_eq!(
                        batched.row(r),
                        want.row(0),
                        "{method:?} ({exec:?}) row {r}"
                    );
                }
            }
        }
    }

    #[test]
    fn calib_merge_takes_max() {
        let mut a = LayerCalib {
            col_absmax: vec![1.0, 5.0],
            sample: None,
        };
        let b = LayerCalib {
            col_absmax: vec![3.0, 2.0],
            sample: None,
        };
        a.merge(&b);
        assert_eq!(a.col_absmax, vec![3.0, 5.0]);
        let mut empty = LayerCalib::default();
        empty.merge(&a);
        assert_eq!(empty.col_absmax, vec![3.0, 5.0]);
    }

    #[test]
    fn method_names_match_paper_rows() {
        assert_eq!(Method::W4A8Rtn.name(), "W4A8 + RTN");
        assert_eq!(
            Method::Rtn { fmt: Format::Nvfp4 }.name(),
            "NVFP4 + RTN"
        );
        assert_eq!(
            Method::ArcQuant { fmt: Format::Nvfp4, max_s: None }.name(),
            "ARCQuant"
        );
    }
}
