//! Atom baseline (Zhao et al., 2024): reorder + mixed precision.
//!
//! Atom sorts channels by calibrated magnitude, keeps the top outlier
//! channels in INT8 (group 128) and quantizes the bulk to INT4 (group
//! 128). The paper's §3.1 hardware argument: on Blackwell this mixing of
//! granularities/precisions precludes unified Tensor-Core MMA, so Atom's
//! accuracy comes at a throughput cost ARCQuant avoids. Here we reproduce
//! Atom's *numerics* (for the accuracy tables) and model its kernel cost
//! separately in [`crate::costmodel`].

use super::LayerCalib;
use crate::quant::Permutation;
use crate::tensor::{matmul_nt, Mat};

/// Atom's default group size for both INT4 and INT8 regions.
pub const ATOM_GROUP: usize = 128;
/// Atom's default number of INT8 outlier channels (the official config
/// keeps 128 channels in higher precision).
pub const ATOM_DEFAULT_OUTLIERS: usize = 128;

pub struct AtomLinear {
    perm: Permutation,
    /// INT8-quantized outlier weight region [M, S8].
    w_outlier: Mat,
    /// INT4-quantized bulk weight region [M, K−S8].
    w_bulk: Mat,
    s8: usize,
}

impl AtomLinear {
    pub fn prepare(w: &Mat, calib: &LayerCalib, outlier_channels: usize) -> AtomLinear {
        let k = w.cols;
        let s8 = outlier_channels.min(k);
        let perm = Permutation::sort_desc(&calib.col_absmax);
        let wr = perm.apply_cols(w);
        let idx_out: Vec<usize> = (0..s8).collect();
        let idx_bulk: Vec<usize> = (s8..k).collect();
        let w_outlier = qdq_int(&wr.select_cols(&idx_out), 8);
        let w_bulk = qdq_int(&wr.select_cols(&idx_bulk), 4);
        AtomLinear {
            perm,
            w_outlier,
            w_bulk,
            s8,
        }
    }

    pub fn forward(&self, x: &Mat) -> Mat {
        let xr = self.perm.apply_cols(x);
        let k = xr.cols;
        let idx_out: Vec<usize> = (0..self.s8).collect();
        let idx_bulk: Vec<usize> = (self.s8..k).collect();
        let x_out = qdq_int(&xr.select_cols(&idx_out), 8);
        let x_bulk = qdq_int(&xr.select_cols(&idx_bulk), 4);
        // Two GEMMs accumulated — the "complex kernel logic" Atom needs.
        let mut y = matmul_nt(&x_bulk, &self.w_bulk);
        if self.s8 > 0 {
            let y_out = matmul_nt(&x_out, &self.w_outlier);
            for (a, b) in y.data.iter_mut().zip(&y_out.data) {
                *a += b;
            }
        }
        y
    }

    pub fn outliers(&self) -> usize {
        self.s8
    }

    /// Output dimension M of the prepared layer.
    pub fn out_dim(&self) -> usize {
        self.w_bulk.rows
    }
}

/// Group-wise symmetric integer QDQ with Atom's group size.
fn qdq_int(m: &Mat, bits: u32) -> Mat {
    let codec = crate::numerics::IntCodec { bits };
    let mut out = m.clone();
    for r in 0..out.rows {
        let row = out.row_mut(r);
        for block in row.chunks_mut(ATOM_GROUP) {
            let amax = block.iter().fold(0.0f32, |mm, &v| mm.max(v.abs()));
            let s = codec.scale_for(amax);
            for v in block.iter_mut() {
                *v = codec.qdq(*v, s);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{stats, Prng};

    fn workload(seed: u64) -> (Mat, Mat, LayerCalib) {
        let mut rng = Prng::new(seed);
        let x = Mat::from_fn(16, 256, |_, c| {
            let v = rng.normal();
            if c % 29 == 3 {
                v * 45.0
            } else {
                v
            }
        });
        let mut w = Mat::zeros(16, 256);
        w.fill_random_normal(&mut rng, 0.4);
        let calib = LayerCalib::from_activations(&x);
        (x, w, calib)
    }

    #[test]
    fn atom_beats_plain_int4_rtn() {
        let (x, w, calib) = workload(100);
        let y_ref = matmul_nt(&x, &w);
        let atom = AtomLinear::prepare(&w, &calib, ATOM_DEFAULT_OUTLIERS).forward(&x);
        let rtn = matmul_nt(&qdq_int(&x, 4), &qdq_int(&w, 4));
        let e_atom = stats::mse(&atom.data, &y_ref.data);
        let e_rtn = stats::mse(&rtn.data, &y_ref.data);
        assert!(e_atom < e_rtn, "atom {e_atom} !< int4 rtn {e_rtn}");
    }

    #[test]
    fn zero_outliers_reduces_to_int4() {
        let (x, w, calib) = workload(101);
        let atom = AtomLinear::prepare(&w, &calib, 0);
        assert_eq!(atom.outliers(), 0);
        let y = atom.forward(&x);
        // equals reordered INT4 GEMM == plain INT4 GEMM? Reordering both
        // operands preserves the product, so compare against plain INT4
        // only up to group-boundary effects; check shape + finiteness +
        // better-than-nothing error instead.
        assert_eq!((y.rows, y.cols), (16, 16));
        assert!(y.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn outliers_capped_at_k() {
        let (x, w, calib) = workload(102);
        let atom = AtomLinear::prepare(&w, &calib, 10_000);
        assert_eq!(atom.outliers(), 256);
        let y = atom.forward(&x);
        // All channels INT8 → very accurate.
        let y_ref = matmul_nt(&x, &w);
        assert!(stats::rel_frob_err(&y.data, &y_ref.data) < 0.05);
    }

    #[test]
    fn int8_region_much_more_accurate_than_int4() {
        let mut rng = Prng::new(103);
        let m = Mat::from_fn(8, 128, |_, _| rng.normal() * 5.0);
        let e8 = stats::mse(&qdq_int(&m, 8).data, &m.data);
        let e4 = stats::mse(&qdq_int(&m, 4).data, &m.data);
        assert!(e8 < e4 / 50.0);
    }
}
