//! Deterministic fault injection for the serving stack.
//!
//! A [`Faults`] plan arms named **fault points** — fixed call sites such
//! as `tick_decode` (scheduler decode tick), `tick_prefill` (scheduler
//! prefill tick), `kv_alloc` (KV page extension during decode) and
//! `socket_write` (HTTP streaming chunk write) — to fire exactly once,
//! on the *nth* pass through the site. The spec grammar is
//!
//! ```text
//! ARCQUANT_FAULTS="site:nth[:panic|err][,site:nth[:mode]...]"
//! ```
//!
//! e.g. `ARCQUANT_FAULTS=tick_decode:3:panic` panics on the third decode
//! tick of the process. `panic` (the default mode) unwinds at the site —
//! the supervised scheduler must contain it; `err` makes the site take
//! its native error path instead (sites without one escalate `err` to
//! `panic`, documented per call site).
//!
//! Determinism is the point: the nth-hit counter makes a fault land on
//! the same tick every run, so recovery behavior is pinned by ordinary
//! assertions rather than stress-and-hope. Plans are *values*, not
//! process globals: the CLI builds one from the environment
//! ([`Faults::from_env`]) and hands it to the server config, while tests
//! and benches construct plans with [`Faults::parse`] — concurrent tests
//! with different plans never interfere. Cloning a plan shares its hit
//! counters (the scheduler and connection handlers must count against
//! the same budget), and the unarmed case is a single is-empty branch.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// What firing a fault point does.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultMode {
    /// Unwind at the site (`panic!`); the default.
    Panic,
    /// Make the site take its native error path.
    Err,
}

#[derive(Debug)]
struct PlanState {
    site: String,
    nth: u64,
    mode: FaultMode,
    hits: AtomicU64,
}

/// An armed (possibly empty) set of fault plans. See the module docs
/// for the spec grammar and sharing semantics.
#[derive(Clone, Debug, Default)]
pub struct Faults {
    plans: Arc<[PlanState]>,
}

impl Faults {
    /// The unarmed plan: every [`Faults::point`] is a no-op.
    pub fn none() -> Faults {
        Faults::default()
    }

    /// Parse a `site:nth[:mode]` spec list (see module docs). `nth` is
    /// 1-based; mode defaults to `panic`.
    pub fn parse(spec: &str) -> Result<Faults, String> {
        let mut plans = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() < 2 || fields.len() > 3 {
                return Err(format!(
                    "fault spec {part:?}: want site:nth[:panic|err]"
                ));
            }
            let site = fields[0].trim();
            if site.is_empty() {
                return Err(format!("fault spec {part:?}: empty site name"));
            }
            let nth: u64 = fields[1]
                .trim()
                .parse()
                .map_err(|_| format!("fault spec {part:?}: bad nth"))?;
            if nth == 0 {
                return Err(format!("fault spec {part:?}: nth is 1-based"));
            }
            let mode = match fields.get(2).map(|m| m.trim()) {
                None | Some("panic") => FaultMode::Panic,
                Some("err") => FaultMode::Err,
                Some(other) => {
                    return Err(format!(
                        "fault spec {part:?}: unknown mode {other:?}"
                    ))
                }
            };
            plans.push(PlanState {
                site: site.to_string(),
                nth,
                mode,
                hits: AtomicU64::new(0),
            });
        }
        Ok(Faults { plans: plans.into() })
    }

    /// The process-level plan from `ARCQUANT_FAULTS` (unset/empty =
    /// unarmed). An invalid spec panics at startup: a silently ignored
    /// fault plan would make a chaos run report vacuous success.
    pub fn from_env() -> Faults {
        match std::env::var("ARCQUANT_FAULTS") {
            Ok(s) if !s.trim().is_empty() => match Faults::parse(&s) {
                Ok(f) => f,
                Err(e) => panic!("invalid ARCQUANT_FAULTS: {e}"),
            },
            _ => Faults::none(),
        }
    }

    /// Is any fault armed at all?
    pub fn armed(&self) -> bool {
        !self.plans.is_empty()
    }

    /// Record one pass through the named fault point. Returns `true`
    /// when an `err`-mode fault fires here (the caller takes its error
    /// path); panics when a `panic`-mode fault fires; `false` otherwise
    /// — including always, when nothing is armed.
    pub fn point(&self, site: &str) -> bool {
        if self.plans.is_empty() {
            return false;
        }
        for p in self.plans.iter() {
            if p.site == site {
                let hit = p.hits.fetch_add(1, Ordering::Relaxed) + 1;
                if hit == p.nth {
                    match p.mode {
                        FaultMode::Panic => {
                            panic!("injected fault: {site} (hit {hit})")
                        }
                        FaultMode::Err => return true,
                    }
                }
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_points_are_noops() {
        let f = Faults::none();
        assert!(!f.armed());
        for _ in 0..1000 {
            assert!(!f.point("tick_decode"));
        }
    }

    #[test]
    fn err_mode_fires_exactly_on_the_nth_hit() {
        let f = Faults::parse("kv_alloc:3:err").unwrap();
        assert!(f.armed());
        assert!(!f.point("kv_alloc"));
        assert!(!f.point("tick_decode"), "other sites never fire");
        assert!(!f.point("kv_alloc"));
        assert!(f.point("kv_alloc"), "third hit fires");
        assert!(!f.point("kv_alloc"), "fires once, not every nth");
    }

    #[test]
    #[should_panic(expected = "injected fault: tick_decode")]
    fn panic_mode_panics_at_the_site() {
        let f = Faults::parse("tick_decode:1").unwrap();
        f.point("tick_decode");
    }

    #[test]
    fn clones_share_hit_counters() {
        let f = Faults::parse("socket_write:2:err").unwrap();
        let g = f.clone();
        assert!(!f.point("socket_write"));
        assert!(g.point("socket_write"), "clone sees the first hit");
    }

    #[test]
    fn multi_site_specs_parse() {
        let f = Faults::parse("tick_decode:2:panic, kv_alloc:1:err").unwrap();
        assert!(f.point("kv_alloc"));
        assert!(!f.point("tick_decode"));
    }

    #[test]
    fn bad_specs_are_rejected() {
        assert!(Faults::parse("tick_decode").is_err());
        assert!(Faults::parse("tick_decode:zero").is_err());
        assert!(Faults::parse("tick_decode:0").is_err());
        assert!(Faults::parse(":1").is_err());
        assert!(Faults::parse("a:1:b:c").is_err());
        assert!(Faults::parse("site:1:explode").is_err());
        assert!(Faults::parse("").unwrap().plans.is_empty());
    }
}
