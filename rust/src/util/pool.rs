//! Data-parallel helpers over a **persistent worker pool** (rayon is
//! unavailable offline). Work is split into contiguous chunks; the
//! decomposition is identical to single-threaded execution (each output
//! element is produced by the same code over the same inputs), so results
//! are bit-for-bit independent of the thread count.
//!
//! v2: the pool is lazily initialized once per process (`OnceLock`) and
//! fed through a locked queue + condvar. A `par_chunks_mut`/`par_map`
//! call enqueues one job per worker piece, runs the first piece on the
//! calling thread, then helps drain the queue until its own jobs are
//! done — so per-call dispatch cost is a queue round-trip instead of the
//! previous `std::thread::scope` spawn/join (≈7 spawns per decode step
//! per layer on the serving hot path). Nested parallel calls from inside
//! a pool job submit to the same queue and are legal at any depth: a
//! waiting caller only ever *helps* (drains its own group's jobs) and
//! re-polls on a timed wait instead of blocking, so every queued job is
//! always reachable by some thread and the pool cannot deadlock — while
//! narrow outer fan-outs (e.g. two eval windows on a 16-worker pool)
//! still spread their inner GEMMs across the idle workers.
//!
//! Also hosts small thread-local scratch-buffer pools ([`take_f32`] /
//! [`put_f32`], [`take_i32`] / [`put_i32`]) so per-forward hot paths
//! (activation quantization, the packed GEMM's decode scratch) reuse
//! allocations instead of churning `Vec`s on every call.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

// ---------------------------------------------------------------------------
// Thread-count configuration
// ---------------------------------------------------------------------------

/// `ARCQUANT_THREADS` parsed once per process (the pre-v2 code re-read the
/// environment on every parallel call — measurable on the decode path).
static ENV_THREADS: OnceLock<usize> = OnceLock::new();

/// Runtime override (0 = none). Tests use this to pin the worker count
/// in-process, where re-exporting the environment would be racy.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

fn configured_threads() -> usize {
    *ENV_THREADS.get_or_init(|| {
        if let Ok(v) = std::env::var("ARCQUANT_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get().min(16))
            .unwrap_or(4)
    })
}

/// Number of workers to use: respects `ARCQUANT_THREADS` (read once per
/// process), defaults to the available parallelism capped at 16. A
/// [`set_thread_override`] value, when present, wins.
pub fn num_threads() -> usize {
    match THREAD_OVERRIDE.load(Ordering::Relaxed) {
        0 => configured_threads(),
        n => n,
    }
}

/// Override the worker count at runtime (`None` restores the environment
/// default). Results never depend on the thread count — this exists so
/// the determinism pins can compare single- vs multi-threaded execution
/// within one process. Global: affects every subsequent parallel call.
pub fn set_thread_override(n: Option<usize>) {
    THREAD_OVERRIDE.store(n.map_or(0, |v| v.max(1)), Ordering::Relaxed);
}

// ---------------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------------

/// Completion latch for one `scope_run` call, shared by its jobs.
struct Group {
    state: Mutex<GroupState>,
    done: Condvar,
}

struct GroupState {
    remaining: usize,
    /// First panic payload observed in a job; re-raised on the caller.
    panic: Option<Box<dyn std::any::Any + Send + 'static>>,
}

struct Job {
    task: Box<dyn FnOnce() + Send + 'static>,
    group: Arc<Group>,
}

impl Job {
    fn run(self) {
        let res = catch_unwind(AssertUnwindSafe(self.task));
        let mut st = self.group.state.lock().unwrap();
        if let Err(payload) = res {
            if st.panic.is_none() {
                st.panic = Some(payload);
            }
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            self.group.done.notify_all();
        }
    }
}

struct PoolShared {
    queue: Mutex<VecDeque<Job>>,
    ready: Condvar,
}

impl PoolShared {
    fn submit(&self, job: Job) {
        self.queue.lock().unwrap().push_back(job);
        self.ready.notify_one();
    }

    /// Pop the oldest queued job belonging to `group`, if any. Helping is
    /// group-scoped so a waiting caller never burns its stack (or delays
    /// its own completion) executing an unrelated fan-out's jobs.
    fn try_pop_group(&self, group: &Arc<Group>) -> Option<Job> {
        let mut q = self.queue.lock().unwrap();
        let i = q.iter().position(|j| Arc::ptr_eq(&j.group, group))?;
        q.remove(i)
    }

    /// Worker body: block on the queue forever. Workers are detached and
    /// idle on the condvar between calls; they do not keep the process
    /// alive.
    fn worker_loop(&self) {
        loop {
            let job = {
                let mut q = self.queue.lock().unwrap();
                loop {
                    if let Some(j) = q.pop_front() {
                        break j;
                    }
                    q = self.ready.wait(q).unwrap();
                }
            };
            job.run();
        }
    }

    /// Caller-side wait: keep executing `group`'s queued jobs until the
    /// group has fully completed. Never blocks while its own work is
    /// queued, and the timed re-poll below never blocks indefinitely —
    /// together these make nested submission deadlock-free: every queued
    /// job is reachable by an idle worker or by its waiting owner.
    fn help_until_done(&self, group: &Arc<Group>) {
        loop {
            {
                let st = group.state.lock().unwrap();
                if st.remaining == 0 {
                    return;
                }
            }
            if let Some(job) = self.try_pop_group(group) {
                job.run();
                continue;
            }
            let st = group.state.lock().unwrap();
            if st.remaining == 0 {
                return;
            }
            // Timed wait: re-polls the queue so the caller resumes helping
            // if new jobs land while ours run on busy workers.
            let _ = group
                .done
                .wait_timeout(st, Duration::from_micros(200))
                .unwrap();
        }
    }
}

static POOL: OnceLock<Arc<PoolShared>> = OnceLock::new();

fn pool() -> &'static Arc<PoolShared> {
    POOL.get_or_init(|| {
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(VecDeque::new()),
            ready: Condvar::new(),
        });
        // The caller of every parallel region executes one piece itself,
        // so `configured - 1` workers already saturate the default
        // configuration; spawn `configured` to also cover overrides and
        // concurrent top-level callers (extra workers just idle).
        for wi in 0..configured_threads() {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("arcquant-pool-{wi}"))
                .spawn(move || sh.worker_loop())
                .expect("failed to spawn pool worker");
        }
        shared
    })
}

/// Run `jobs` to completion: the first on the calling thread, the rest on
/// the persistent pool. Blocks until every job has finished and re-raises
/// the first panic observed (caller's own piece first).
fn scope_run<'s>(mut jobs: Vec<Box<dyn FnOnce() + Send + 's>>) {
    if jobs.is_empty() {
        return;
    }
    let local = jobs.remove(0);
    if jobs.is_empty() {
        local();
        return;
    }
    let group = Arc::new(Group {
        state: Mutex::new(GroupState {
            remaining: jobs.len(),
            panic: None,
        }),
        done: Condvar::new(),
    });
    let p = pool();
    for task in jobs {
        // SAFETY: the borrowed-data lifetime `'s` is erased to `'static`
        // here, which is sound because this function does not return (or
        // unwind) until `help_until_done` has observed every job finished
        // — no task can touch its borrows after `'s` expires. Panic
        // payloads are `Any + 'static` by construction, so nothing
        // borrowed escapes through the panic slot either.
        let task: Box<dyn FnOnce() + Send + 'static> =
            unsafe { std::mem::transmute(task) };
        p.submit(Job {
            task,
            group: Arc::clone(&group),
        });
    }
    // The caller's own piece must not unwind past the latch while workers
    // still hold borrows into the scope: catch, wait, then re-raise.
    let local_res = catch_unwind(AssertUnwindSafe(local));
    p.help_until_done(&group);
    if let Err(payload) = local_res {
        resume_unwind(payload);
    }
    let pool_panic = group.state.lock().unwrap().panic.take();
    if let Some(payload) = pool_panic {
        resume_unwind(payload);
    }
}

/// Apply `f(start, chunk)` to disjoint mutable chunks of `data` in parallel.
/// `start` is the element offset of the chunk within `data`. Chunk
/// boundaries (and therefore results) are identical at every thread count.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let nt = num_threads();
    if nt <= 1 || data.len() <= chunk_len {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(ci * chunk_len, chunk);
        }
        return;
    }
    let n_chunks = data.len().div_ceil(chunk_len);
    let per_worker = n_chunks.div_ceil(nt);
    let stride = per_worker * chunk_len;
    let f = &f;
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
        .chunks_mut(stride)
        .enumerate()
        .map(|(wi, piece)| {
            Box::new(move || {
                let base = wi * stride;
                for (ci, chunk) in piece.chunks_mut(chunk_len).enumerate() {
                    f(base + ci * chunk_len, chunk);
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    scope_run(jobs);
}

/// Parallel map over indices [0, n): returns `vec![f(0), f(1), ..]`.
pub fn par_map<R: Send, F>(n: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let nt = num_threads().min(n.max(1));
    if nt <= 1 {
        return (0..n).map(f).collect();
    }
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let per = n.div_ceil(nt);
    let f = &f;
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = results
        .chunks_mut(per)
        .enumerate()
        .map(|(wi, slot_chunk)| {
            Box::new(move || {
                for (j, slot) in slot_chunk.iter_mut().enumerate() {
                    *slot = Some(f(wi * per + j));
                }
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    scope_run(jobs);
    results.into_iter().map(|r| r.unwrap()).collect()
}

// ---------------------------------------------------------------------------
// Thread-local scratch-buffer pools
// ---------------------------------------------------------------------------

// Per-thread free lists. Bounded so a burst of large buffers cannot pin
// memory forever; each worker thread keeps its own list, so no locking.
const POOL_CAP: usize = 8;

thread_local! {
    static F32_BUFS: RefCell<Vec<Vec<f32>>> = RefCell::new(Vec::new());
    static I32_BUFS: RefCell<Vec<Vec<i32>>> = RefCell::new(Vec::new());
    static I16_BUFS: RefCell<Vec<Vec<i16>>> = RefCell::new(Vec::new());
}

/// Take a zero-filled `Vec<f32>` of `len` from the thread-local pool
/// (allocating only when the pool is empty). Pair with [`put_f32`].
pub fn take_f32(len: usize) -> Vec<f32> {
    match F32_BUFS.with(|p| p.borrow_mut().pop()) {
        Some(mut v) => {
            v.clear();
            v.resize(len, 0.0);
            v
        }
        None => vec![0.0; len],
    }
}

/// Return a buffer taken with [`take_f32`] to the pool.
pub fn put_f32(v: Vec<f32>) {
    F32_BUFS.with(|p| {
        let mut p = p.borrow_mut();
        if p.len() < POOL_CAP {
            p.push(v);
        }
    });
}

/// Take a zero-filled `Vec<i32>` of `len` from the thread-local pool.
pub fn take_i32(len: usize) -> Vec<i32> {
    match I32_BUFS.with(|p| p.borrow_mut().pop()) {
        Some(mut v) => {
            v.clear();
            v.resize(len, 0);
            v
        }
        None => vec![0; len],
    }
}

/// Return a buffer taken with [`take_i32`] to the pool.
pub fn put_i32(v: Vec<i32>) {
    I32_BUFS.with(|p| {
        let mut p = p.borrow_mut();
        if p.len() < POOL_CAP {
            p.push(v);
        }
    });
}

/// Take a zero-filled `Vec<i16>` of `len` from the thread-local pool
/// (the packed GEMM's decoded-panel scratch).
pub fn take_i16(len: usize) -> Vec<i16> {
    match I16_BUFS.with(|p| p.borrow_mut().pop()) {
        Some(mut v) => {
            v.clear();
            v.resize(len, 0);
            v
        }
        None => vec![0; len],
    }
}

/// Return a buffer taken with [`take_i16`] to the pool.
pub fn put_i16(v: Vec<i16>) {
    I16_BUFS.with(|p| {
        let mut p = p.borrow_mut();
        if p.len() < POOL_CAP {
            p.push(v);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut v = vec![0usize; 1003];
        par_chunks_mut(&mut v, 64, |start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = start + i;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn par_map_matches_serial() {
        let out = par_map(257, |i| i * i);
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<usize> = par_map(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn repeated_calls_reuse_the_pool() {
        // The serving decode loop issues thousands of small parallel
        // regions; they must all complete against the same worker set.
        for round in 0..200 {
            let mut v = vec![0usize; 97];
            par_chunks_mut(&mut v, 8, |start, chunk| {
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x = start + i + round;
                }
            });
            for (i, &x) in v.iter().enumerate() {
                assert_eq!(x, i + round);
            }
        }
    }

    #[test]
    fn nested_parallel_calls_complete() {
        // par_map jobs that themselves call par_chunks_mut (the
        // eval-pipeline shape: windows in parallel, GEMMs inside). Nested
        // calls submit to the same queue and their owners help-drain —
        // most importantly, this must not deadlock the pool.
        let out = par_map(8, |i| {
            let mut v = vec![0usize; 64];
            par_chunks_mut(&mut v, 4, |start, chunk| {
                for (j, x) in chunk.iter_mut().enumerate() {
                    *x = i + start + j;
                }
            });
            v.iter().sum::<usize>()
        });
        for (i, &s) in out.iter().enumerate() {
            let want: usize = (0..64).map(|j| i + j).sum();
            assert_eq!(s, want);
        }
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            let mut v = vec![0u32; 256];
            par_chunks_mut(&mut v, 1, |start, _| {
                if start == 200 {
                    panic!("boom in chunk");
                }
            });
        }));
        assert!(caught.is_err(), "panic in a parallel chunk must propagate");
        // ...and the pool must still work afterwards.
        let out = par_map(32, |i| i + 1);
        assert_eq!(out[31], 32);
    }

    // NOTE: set_thread_override is process-global, so its behavior is
    // tested only in rust/tests/integration_determinism.rs (its own test
    // binary) — a unit test here would race the other library tests.

    #[test]
    fn scratch_pool_recycles() {
        let a = take_f32(100);
        assert!(a.iter().all(|&x| x == 0.0));
        let ptr = a.as_ptr() as usize;
        let cap = a.capacity();
        put_f32(a);
        let b = take_f32(50);
        // same allocation comes back (capacity preserved, zeroed contents)
        assert_eq!(b.as_ptr() as usize, ptr);
        assert!(b.capacity() >= 50 && cap >= 100);
        assert!(b.iter().all(|&x| x == 0.0));
        put_f32(b);

        let c = take_i32(16);
        assert_eq!(c.len(), 16);
        put_i32(c);
    }

    #[test]
    fn chunk_len_larger_than_data() {
        let mut v = vec![1u32; 10];
        par_chunks_mut(&mut v, 100, |start, chunk| {
            assert_eq!(start, 0);
            for x in chunk.iter_mut() {
                *x = 2;
            }
        });
        assert!(v.iter().all(|&x| x == 2));
    }
}
