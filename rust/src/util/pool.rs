//! Scoped data-parallel helpers over std::thread (rayon is unavailable
//! offline). Work is split into contiguous chunks, one per worker.
//!
//! Also hosts small thread-local scratch-buffer pools ([`take_f32`] /
//! [`put_f32`], [`take_i32`] / [`put_i32`]) so per-forward hot paths
//! (activation quantization, the packed GEMM's decode scratch) reuse
//! allocations instead of churning `Vec`s on every call.

use std::cell::RefCell;

/// Number of workers to use: respects `ARCQUANT_THREADS`, defaults to the
/// available parallelism, capped at 16.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("ARCQUANT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get().min(16))
        .unwrap_or(4)
}

/// Apply `f(start, chunk)` to disjoint mutable chunks of `data` in parallel.
/// `start` is the element offset of the chunk within `data`.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let nt = num_threads();
    if nt <= 1 || data.len() <= chunk_len {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(ci * chunk_len, chunk);
        }
        return;
    }
    let n_chunks = data.len().div_ceil(chunk_len);
    let per_worker = n_chunks.div_ceil(nt);
    std::thread::scope(|scope| {
        for (wi, piece) in data.chunks_mut(per_worker * chunk_len).enumerate() {
            let f = &f;
            scope.spawn(move || {
                let base = wi * per_worker * chunk_len;
                for (ci, chunk) in piece.chunks_mut(chunk_len).enumerate() {
                    f(base + ci * chunk_len, chunk);
                }
            });
        }
    });
}

// Per-thread free lists. Bounded so a burst of large buffers cannot pin
// memory forever; each worker thread keeps its own list, so no locking.
const POOL_CAP: usize = 8;

thread_local! {
    static F32_BUFS: RefCell<Vec<Vec<f32>>> = RefCell::new(Vec::new());
    static I32_BUFS: RefCell<Vec<Vec<i32>>> = RefCell::new(Vec::new());
}

/// Take a zero-filled `Vec<f32>` of `len` from the thread-local pool
/// (allocating only when the pool is empty). Pair with [`put_f32`].
pub fn take_f32(len: usize) -> Vec<f32> {
    match F32_BUFS.with(|p| p.borrow_mut().pop()) {
        Some(mut v) => {
            v.clear();
            v.resize(len, 0.0);
            v
        }
        None => vec![0.0; len],
    }
}

/// Return a buffer taken with [`take_f32`] to the pool.
pub fn put_f32(v: Vec<f32>) {
    F32_BUFS.with(|p| {
        let mut p = p.borrow_mut();
        if p.len() < POOL_CAP {
            p.push(v);
        }
    });
}

/// Take a zero-filled `Vec<i32>` of `len` from the thread-local pool.
pub fn take_i32(len: usize) -> Vec<i32> {
    match I32_BUFS.with(|p| p.borrow_mut().pop()) {
        Some(mut v) => {
            v.clear();
            v.resize(len, 0);
            v
        }
        None => vec![0; len],
    }
}

/// Return a buffer taken with [`take_i32`] to the pool.
pub fn put_i32(v: Vec<i32>) {
    I32_BUFS.with(|p| {
        let mut p = p.borrow_mut();
        if p.len() < POOL_CAP {
            p.push(v);
        }
    });
}

/// Parallel map over indices [0, n): returns `vec![f(0), f(1), ..]`.
pub fn par_map<R: Send, F>(n: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let nt = num_threads().min(n.max(1));
    if nt <= 1 {
        return (0..n).map(f).collect();
    }
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let per = n.div_ceil(nt);
    std::thread::scope(|scope| {
        for (wi, slot_chunk) in results.chunks_mut(per).enumerate() {
            let f = &f;
            scope.spawn(move || {
                for (j, slot) in slot_chunk.iter_mut().enumerate() {
                    *slot = Some(f(wi * per + j));
                }
            });
        }
    });
    results.into_iter().map(|r| r.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut v = vec![0usize; 1003];
        par_chunks_mut(&mut v, 64, |start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = start + i;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn par_map_matches_serial() {
        let out = par_map(257, |i| i * i);
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<usize> = par_map(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn scratch_pool_recycles() {
        let a = take_f32(100);
        assert!(a.iter().all(|&x| x == 0.0));
        let ptr = a.as_ptr() as usize;
        let cap = a.capacity();
        put_f32(a);
        let b = take_f32(50);
        // same allocation comes back (capacity preserved, zeroed contents)
        assert_eq!(b.as_ptr() as usize, ptr);
        assert!(b.capacity() >= 50 && cap >= 100);
        assert!(b.iter().all(|&x| x == 0.0));
        put_f32(b);

        let c = take_i32(16);
        assert_eq!(c.len(), 16);
        put_i32(c);
    }

    #[test]
    fn chunk_len_larger_than_data() {
        let mut v = vec![1u32; 10];
        par_chunks_mut(&mut v, 100, |start, chunk| {
            assert_eq!(start, 0);
            for x in chunk.iter_mut() {
                *x = 2;
            }
        });
        assert!(v.iter().all(|&x| x == 2));
    }
}
