//! Scoped data-parallel helpers over std::thread (rayon is unavailable
//! offline). Work is split into contiguous chunks, one per worker.

/// Number of workers to use: respects `ARCQUANT_THREADS`, defaults to the
/// available parallelism, capped at 16.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("ARCQUANT_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get().min(16))
        .unwrap_or(4)
}

/// Apply `f(start, chunk)` to disjoint mutable chunks of `data` in parallel.
/// `start` is the element offset of the chunk within `data`.
pub fn par_chunks_mut<T: Send, F>(data: &mut [T], chunk_len: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let nt = num_threads();
    if nt <= 1 || data.len() <= chunk_len {
        for (ci, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(ci * chunk_len, chunk);
        }
        return;
    }
    let n_chunks = data.len().div_ceil(chunk_len);
    let per_worker = n_chunks.div_ceil(nt);
    std::thread::scope(|scope| {
        for (wi, piece) in data.chunks_mut(per_worker * chunk_len).enumerate() {
            let f = &f;
            scope.spawn(move || {
                let base = wi * per_worker * chunk_len;
                for (ci, chunk) in piece.chunks_mut(chunk_len).enumerate() {
                    f(base + ci * chunk_len, chunk);
                }
            });
        }
    });
}

/// Parallel map over indices [0, n): returns `vec![f(0), f(1), ..]`.
pub fn par_map<R: Send, F>(n: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Sync,
{
    let nt = num_threads().min(n.max(1));
    if nt <= 1 {
        return (0..n).map(f).collect();
    }
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let per = n.div_ceil(nt);
    std::thread::scope(|scope| {
        for (wi, slot_chunk) in results.chunks_mut(per).enumerate() {
            let f = &f;
            scope.spawn(move || {
                for (j, slot) in slot_chunk.iter_mut().enumerate() {
                    *slot = Some(f(wi * per + j));
                }
            });
        }
    });
    results.into_iter().map(|r| r.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut v = vec![0usize; 1003];
        par_chunks_mut(&mut v, 64, |start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = start + i;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn par_map_matches_serial() {
        let out = par_map(257, |i| i * i);
        for (i, &x) in out.iter().enumerate() {
            assert_eq!(x, i * i);
        }
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<usize> = par_map(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn chunk_len_larger_than_data() {
        let mut v = vec![1u32; 10];
        par_chunks_mut(&mut v, 100, |start, chunk| {
            assert_eq!(start, 0);
            for x in chunk.iter_mut() {
                *x = 2;
            }
        });
        assert!(v.iter().all(|&x| x == 2));
    }
}
