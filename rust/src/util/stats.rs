//! Small statistics helpers shared by the eval harness, the benchmark
//! harness and the report generators.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via nearest-rank on a sorted copy (p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Geometric mean of positive values (1.0 for an empty slice).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    (xs.iter().map(|&x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// Max absolute error.
pub fn max_abs_err(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| ((x - y) as f64).abs())
        .fold(0.0, f64::max)
}

/// Frobenius norm of the difference, matching the paper's
/// ||Y - Q(X)Q(W)^T||_F objective.
pub fn frob_err(a: &[f32], b: &[f32]) -> f64 {
    (mse(a, b) * a.len() as f64).sqrt()
}

/// Relative Frobenius error ||a-b||_F / ||b||_F.
pub fn rel_frob_err(a: &[f32], b: &[f32]) -> f64 {
    let denom = (b.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()).sqrt();
    if denom == 0.0 {
        return 0.0;
    }
    frob_err(a, b) / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn mse_zero_for_identical() {
        let a = [1.0f32, -2.0, 3.5];
        assert_eq!(mse(&a, &a), 0.0);
        assert_eq!(max_abs_err(&a, &a), 0.0);
    }

    #[test]
    fn frob_matches_manual() {
        let a = [3.0f32, 0.0];
        let b = [0.0f32, 4.0];
        assert!((frob_err(&a, &b) - 5.0).abs() < 1e-9);
        assert!((rel_frob_err(&a, &b) - 5.0 / 4.0).abs() < 1e-9);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(mse(&[], &[]), 0.0);
    }
}
