//! Deterministic PRNGs (splitmix64 seeding + xoshiro256** core).
//!
//! All stochastic behaviour in the library (synthetic corpora, calibration
//! sampling, property tests, QuaRot's random Hadamard sign flips) flows
//! through this module so that every experiment is reproducible from a
//! single `u64` seed — mirroring the paper's "seed fixed to 0" statement.

/// splitmix64 step — used to expand a single seed into the xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Prng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (e.g. per-layer, per-worker).
    pub fn fork(&mut self, stream: u64) -> Prng {
        Prng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; throughput is not a concern for data generation).
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.f64()).max(1e-300); // avoid ln(0)
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Random sign, +1.0 or -1.0 (QuaRot diagonal).
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample from an unnormalised discrete distribution.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f32 = weights.iter().sum();
        let mut u = self.f32() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Prng::new(0);
        let mut b = Prng::new(0);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::new(7);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Prng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn categorical_respects_zero_weight() {
        let mut r = Prng::new(9);
        for _ in 0..200 {
            let i = r.categorical(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Prng::new(42);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
