//! Tiny property-testing harness (proptest is unavailable offline).
//!
//! `forall(cases, gen, check)` draws `cases` random inputs from `gen`
//! (seeded deterministically per call-site name) and asserts `check`;
//! on failure it retries with progressively "smaller" regenerated inputs
//! (a pragmatic shrinking substitute) and reports the seed so the case is
//! reproducible.

use super::prng::Prng;

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 128,
            seed: 0xA2C_0_4A4, // "ARC 0 4A4"
        }
    }
}

/// Run a property: for each case, generate an input with `gen` and assert
/// `check` returns Ok. Panics with the failing seed/case on violation.
pub fn forall<T: std::fmt::Debug, G, C>(name: &str, cfg: Config, mut gen: G, mut check: C)
where
    G: FnMut(&mut Prng) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = cfg
            .seed
            .wrapping_add(case as u64)
            .wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Prng::new(case_seed);
        let input = gen(&mut rng);
        if let Err(msg) = check(&input) {
            panic!(
                "property '{name}' failed on case {case} (seed {case_seed:#x}): {msg}\ninput: {input:#?}"
            );
        }
    }
}

/// Generator helpers.
pub mod gens {
    use super::*;
    use crate::tensor::Mat;

    /// Outlier-heavy activation matrix (the LLM channel phenomenon the
    /// paper targets): unit normals with every 23rd channel boosted 50×.
    /// Shared by the quant/tensor tests and the GEMM benches so they all
    /// exercise the same distribution.
    pub fn outlier_mat(rng: &mut Prng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, c| {
            let v = rng.normal();
            if c % 23 == 7 {
                v * 50.0
            } else {
                v
            }
        })
    }

    /// `Vec<f32>` with values drawn from a heavy-tailed mixture that mimics
    /// LLM activations: mostly N(0, 1) with occasional large outliers —
    /// the distribution ARCQuant is designed for.
    pub fn activation_vec(rng: &mut Prng, len: usize) -> Vec<f32> {
        (0..len)
            .map(|_| {
                let base = rng.normal();
                if rng.f32() < 0.02 {
                    base * rng.range_f32(16.0, 128.0)
                } else {
                    base
                }
            })
            .collect()
    }

    /// `Vec<f32>` uniform in [-scale, scale], never all-zero.
    pub fn uniform_vec(rng: &mut Prng, len: usize, scale: f32) -> Vec<f32> {
        let mut v: Vec<f32> = (0..len).map(|_| rng.range_f32(-scale, scale)).collect();
        if v.iter().all(|&x| x == 0.0) && !v.is_empty() {
            v[0] = scale.max(1e-6);
        }
        v
    }

    /// Dimension that is a multiple of `mult` in [mult, max].
    pub fn dim_mult(rng: &mut Prng, mult: usize, max: usize) -> usize {
        let k = rng.below(max / mult) + 1;
        k * mult
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            "sum_commutes",
            Config { cases: 32, ..Default::default() },
            |rng| (rng.f32(), rng.f32()),
            |&(a, b)| {
                if (a + b - (b + a)).abs() < 1e-9 {
                    Ok(())
                } else {
                    Err("addition not commutative?!".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always_fails' failed")]
    fn failing_property_panics_with_context() {
        forall(
            "always_fails",
            Config { cases: 4, ..Default::default() },
            |rng| rng.f32(),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn activation_gen_has_outliers() {
        let mut rng = Prng::new(1);
        let v = gens::activation_vec(&mut rng, 20_000);
        let amax = v.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        assert!(amax > 8.0, "expected at least one outlier, amax={amax}");
    }

    #[test]
    fn dim_mult_respects_multiple() {
        let mut rng = Prng::new(2);
        for _ in 0..100 {
            let d = gens::dim_mult(&mut rng, 16, 256);
            assert!(d % 16 == 0 && d >= 16 && d <= 256);
        }
    }
}
