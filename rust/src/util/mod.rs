//! In-tree infrastructure substrate.
//!
//! This build environment is fully offline and vendors only the `xla` crate
//! closure, so everything a production framework would normally pull from
//! crates.io (CLI parsing, JSON, a thread pool, seeded PRNGs, a benchmark
//! harness, a property-testing harness) is implemented here from scratch.
//! Each sub-module is small, dependency-free and unit-tested.

pub mod bench;
pub mod cli;
pub mod fault;
pub mod json;
pub mod pool;
pub mod prng;
pub mod prop;
pub mod stats;

pub use prng::Prng;

/// Format a float with a fixed number of decimals, paper-table style.
pub fn fmt_f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Human-readable byte count (GiB with 2 decimals, matching paper tables).
pub fn fmt_gb(bytes: u64) -> String {
    format!("{:.2}", bytes as f64 / (1024.0 * 1024.0 * 1024.0))
}

/// Simple wall-clock timer returning milliseconds.
pub struct Timer(std::time::Instant);

impl Timer {
    pub fn start() -> Self {
        Timer(std::time::Instant::now())
    }
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
    pub fn us(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_f(1.23456, 2), "1.23");
        assert_eq!(fmt_gb(1 << 30), "1.00");
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.ms() >= 1.0);
        assert!(t.us() >= t.ms()); // us reading taken later, and 1000x scale
    }
}
