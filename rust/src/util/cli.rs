//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports `program <subcommand> --flag value --switch` style. Flags can
//! be given as `--key value` or `--key=value`. Unknown flags are an error
//! so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut it = raw.into_iter().peekable();
        let mut args = Args {
            subcommand: None,
            flags: BTreeMap::new(),
            positional: Vec::new(),
        };
        // First non-flag token is the subcommand.
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                args.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag
                    // (then it's a boolean switch).
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            args.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            args.flags.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str_flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_flag(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .map_err(|e| format!("--{key} expects an integer, got '{v}': {e}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .map_err(|e| format!("--{key} expects an integer, got '{v}': {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|e| format!("--{key} expects a number, got '{v}': {e}")),
        }
    }

    pub fn bool_flag(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// Error if a flag outside `allowed` was supplied.
    pub fn reject_unknown(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(format!(
                    "unknown flag --{k}; allowed: {}",
                    allowed.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["serve", "--port", "8080", "--verbose", "--name=m7b"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.usize_or("port", 0).unwrap(), 8080);
        assert!(a.bool_flag("verbose"));
        assert_eq!(a.str_flag("name"), Some("m7b"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["eval"]);
        assert_eq!(a.usize_or("batch", 4).unwrap(), 4);
        assert_eq!(a.f64_or("alpha", 0.5).unwrap(), 0.5);
        assert_eq!(a.str_or("method", "arcquant"), "arcquant");
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["x", "--offset", "-3"]);
        // "-3" doesn't start with "--" so it's consumed as the value.
        assert_eq!(a.str_flag("offset"), Some("-3"));
    }

    #[test]
    fn bad_integer_errors() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn reject_unknown_flags() {
        let a = parse(&["x", "--good", "1", "--oops", "2"]);
        assert!(a.reject_unknown(&["good"]).is_err());
        assert!(a.reject_unknown(&["good", "oops"]).is_ok());
    }

    #[test]
    fn positional_collected() {
        let a = parse(&["run", "file1", "--k", "v", "file2"]);
        assert_eq!(a.positional, vec!["file1", "file2"]);
    }
}
