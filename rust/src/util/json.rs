//! Minimal JSON writer + parser (no external deps).
//!
//! Used for calibration artifacts (reorder indices, per-layer S), metrics
//! dumps and report series. Supports the full JSON data model; numbers are
//! parsed as f64 and integers preserved exactly up to 2^53.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn from_usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn to_f32s(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|j| j.as_f64()).map(|n| n as f32).collect())
    }

    pub fn to_usizes(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|j| j.as_usize()).collect())
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, j) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    j.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| e.to_string())?
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number at byte {start}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Advance by full UTF-8 character.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", Json::Str("o_proj".into()))
            .set("s", Json::Num(128.0))
            .set("idx", Json::from_usizes(&[3, 1, 2]))
            .set("flag", Json::Bool(true))
            .set("none", Json::Null);
        let text = j.dump();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2.5,-3e2],"b":{"c":"x\ny"}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(
            j.get("b").unwrap().get("c").unwrap().as_str(),
            Some("x\ny")
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn string_escapes() {
        let j = Json::Str("quote\" slash\\ nl\n tab\t".into());
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn unicode_roundtrip() {
        let j = Json::Str("天津大学 — ARCQuant".into());
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn f32_vec_roundtrip() {
        let xs = vec![0.5f32, -2.25, 1e-3];
        let j = Json::from_f32s(&xs);
        assert_eq!(Json::parse(&j.dump()).unwrap().to_f32s().unwrap(), xs);
    }

    #[test]
    fn integers_exact() {
        let j = Json::Num(123456789.0);
        assert_eq!(j.dump(), "123456789");
    }
}
