//! Minimal JSON writer + parser (no external deps).
//!
//! Used for calibration artifacts (reorder indices, per-layer S), metrics
//! dumps, report series — and, since the HTTP serving frontend, for
//! **untrusted network request bodies**, which is why the parser is
//! hardened: nesting depth is capped (recursive descent would otherwise
//! be a stack-overflow lever — [`Json::parse_with_depth`] lets the
//! server use a tight cap), non-finite numbers (`1e999`) are rejected,
//! and `\uXXXX` escapes decode UTF-16 surrogate pairs instead of
//! replacing them. Supports the full JSON data model; numbers are parsed
//! as f64 and integers preserved exactly up to 2^53.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn from_f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn from_usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn to_f32s(&self) -> Option<Vec<f32>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|j| j.as_f64()).map(|n| n as f32).collect())
    }

    pub fn to_usizes(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|v| v.iter().filter_map(|j| j.as_usize()).collect())
    }

    /// Serialize (compact).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, j) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    j.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Maximum nesting depth [`Json::parse`] accepts — generous for
    /// trusted artifacts; network-facing callers should pass something
    /// far tighter to [`Json::parse_with_depth`].
    pub const DEFAULT_MAX_DEPTH: usize = 128;

    /// Parse a JSON document (trusted-input depth limit).
    pub fn parse(text: &str) -> Result<Json, String> {
        Json::parse_with_depth(text, Json::DEFAULT_MAX_DEPTH)
    }

    /// Parse a JSON document, refusing containers nested deeper than
    /// `max_depth` (the recursive-descent hardening knob for untrusted
    /// input).
    pub fn parse_with_depth(text: &str, max_depth: usize) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser {
            b: bytes,
            i: 0,
            depth: 0,
            max_depth,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
    max_depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.nested(Parser::object),
            Some(b'[') => self.nested(Parser::array),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    /// Enter a container, enforcing the nesting-depth cap.
    fn nested(
        &mut self,
        f: fn(&mut Parser<'a>) -> Result<Json, String>,
    ) -> Result<Json, String> {
        if self.depth >= self.max_depth {
            return Err(format!(
                "nesting deeper than {} at byte {}",
                self.max_depth, self.i
            ));
        }
        self.depth += 1;
        let v = f(self);
        self.depth -= 1;
        v
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let n = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|e| e.to_string())?
            .parse::<f64>()
            .map_err(|e| format!("bad number at byte {start}: {e}"))?;
        if !n.is_finite() {
            // 1e999-style overflow: never hand Inf/NaN to consumers
            return Err(format!("number out of range at byte {start}"));
        }
        Ok(Json::Num(n))
    }

    /// Four hex digits starting at byte `at` (the payload of a `\uXXXX`
    /// escape).
    fn hex4(&self, at: usize) -> Result<u32, String> {
        let hex = self.b.get(at..at + 4).ok_or("truncated \\u escape")?;
        u32::from_str_radix(
            std::str::from_utf8(hex).map_err(|e| e.to_string())?,
            16,
        )
        .map_err(|e| e.to_string())
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let code = self.hex4(self.i + 1)?;
                            if (0xD800..0xDC00).contains(&code) {
                                // UTF-16 high surrogate: combine with an
                                // immediately following \uXXXX low half
                                let follows = self.b.get(self.i + 5)
                                    == Some(&b'\\')
                                    && self.b.get(self.i + 6) == Some(&b'u');
                                let lo = if follows {
                                    self.hex4(self.i + 7).ok()
                                } else {
                                    None
                                };
                                match lo {
                                    Some(l) if (0xDC00..0xE000).contains(&l) => {
                                        let c = 0x10000
                                            + ((code - 0xD800) << 10)
                                            + (l - 0xDC00);
                                        s.push(
                                            char::from_u32(c).unwrap_or('\u{FFFD}'),
                                        );
                                        self.i += 10;
                                    }
                                    // lone surrogate: replacement char
                                    _ => {
                                        s.push('\u{FFFD}');
                                        self.i += 4;
                                    }
                                }
                            } else {
                                s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                                self.i += 4;
                            }
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Advance by full UTF-8 character.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", Json::Str("o_proj".into()))
            .set("s", Json::Num(128.0))
            .set("idx", Json::from_usizes(&[3, 1, 2]))
            .set("flag", Json::Bool(true))
            .set("none", Json::Null);
        let text = j.dump();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a":[1,2.5,-3e2],"b":{"c":"x\ny"}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(
            j.get("b").unwrap().get("c").unwrap().as_str(),
            Some("x\ny")
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn string_escapes() {
        let j = Json::Str("quote\" slash\\ nl\n tab\t".into());
        let back = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn unicode_roundtrip() {
        let j = Json::Str("天津大学 — ARCQuant".into());
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn f32_vec_roundtrip() {
        let xs = vec![0.5f32, -2.25, 1e-3];
        let j = Json::from_f32s(&xs);
        assert_eq!(Json::parse(&j.dump()).unwrap().to_f32s().unwrap(), xs);
    }

    #[test]
    fn integers_exact() {
        let j = Json::Num(123456789.0);
        assert_eq!(j.dump(), "123456789");
    }

    #[test]
    fn depth_cap_rejects_nesting_bombs() {
        // a 4096-deep array must not be allowed to recurse the stack away
        let bomb = "[".repeat(4096) + &"]".repeat(4096);
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.contains("nesting"), "{err}");
        // mixed object/array nesting counts too
        let mixed = "{\"a\":".repeat(200) + "1" + &"}".repeat(200);
        assert!(Json::parse(&mixed).is_err());
        // a tight limit for network input
        assert!(Json::parse_with_depth("[[[[1]]]]", 3).is_err());
        assert!(Json::parse_with_depth("[[[1]]]", 3).is_ok());
        // depth under the default cap still parses
        let ok = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn non_finite_numbers_rejected() {
        assert!(Json::parse("1e999").is_err());
        assert!(Json::parse("-1e999").is_err());
        assert!(Json::parse("{\"x\":1e999}").is_err());
        // large-but-finite still fine
        assert_eq!(Json::parse("1e300").unwrap().as_f64(), Some(1e300));
    }

    #[test]
    fn surrogate_pairs_decode() {
        // \uD83D\uDE00 is the UTF-16 escape of U+1F600 (the emoji)
        let j = Json::parse(r#""\uD83D\uDE00""#).unwrap();
        assert_eq!(j.as_str(), Some("\u{1F600}"));
        // lone halves degrade to the replacement character, not an error
        assert_eq!(
            Json::parse(r#""\uD83D""#).unwrap().as_str(),
            Some("\u{FFFD}")
        );
        assert_eq!(
            Json::parse(r#""\uDE00""#).unwrap().as_str(),
            Some("\u{FFFD}")
        );
        // BMP escapes unchanged
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str(), Some("A"));
        // a lone high surrogate followed by a non-escape keeps parsing
        assert_eq!(
            Json::parse(r#""\uD83Dxy""#).unwrap().as_str(),
            Some("\u{FFFD}xy")
        );
    }
}
