//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Measures wall-clock over adaptive iteration counts, reports
//! median / mean / p10 / p90 over multiple samples, and prints rows in a
//! stable machine-grep-able format:
//!
//! `BENCH <name> median_us=<..> mean_us=<..> p10_us=<..> p90_us=<..> iters=<..>`

use super::stats;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub median_us: f64,
    pub mean_us: f64,
    pub p10_us: f64,
    pub p90_us: f64,
    pub iters_per_sample: u64,
    pub samples: usize,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "BENCH {} median_us={:.3} mean_us={:.3} p10_us={:.3} p90_us={:.3} iters={}",
            self.name, self.median_us, self.mean_us, self.p10_us, self.p90_us,
            self.iters_per_sample
        );
    }
}

pub struct Bencher {
    /// Target wall time per sample, seconds.
    pub sample_target_s: f64,
    /// Number of samples.
    pub samples: usize,
    /// Warmup time, seconds.
    pub warmup_s: f64,
}

impl Default for Bencher {
    fn default() -> Self {
        // Keep whole-suite runtime bounded; override per bench if needed.
        Bencher {
            sample_target_s: 0.05,
            samples: 12,
            warmup_s: 0.05,
        }
    }
}

/// True when `ARCQUANT_BENCH_SMOKE` is set (and not "0"): benches shrink
/// every shape and skip their `BENCH_*.json` rewrites — the CI smoke step.
pub fn smoke_mode() -> bool {
    std::env::var("ARCQUANT_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            sample_target_s: 0.02,
            samples: 7,
            warmup_s: 0.02,
        }
    }

    /// Minimal-work configuration for [`smoke_mode`] runs.
    pub fn smoke() -> Self {
        Bencher {
            sample_target_s: 0.005,
            samples: 3,
            warmup_s: 0.005,
        }
    }

    /// Benchmark `f`, using its return value to keep the work observable.
    pub fn run<R, F: FnMut() -> R>(&self, name: &str, mut f: F) -> BenchResult {
        // Warmup + iteration-count calibration.
        let mut iters: u64 = 1;
        loop {
            let t = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            let dt = t.elapsed().as_secs_f64();
            if dt >= self.warmup_s {
                // Scale iters so one sample ≈ sample_target_s.
                let per_iter = dt / iters as f64;
                iters = ((self.sample_target_s / per_iter).ceil() as u64).max(1);
                break;
            }
            iters = iters.saturating_mul(2);
        }

        let mut per_iter_us = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..iters {
                std::hint::black_box(f());
            }
            per_iter_us.push(t.elapsed().as_secs_f64() * 1e6 / iters as f64);
        }

        let res = BenchResult {
            name: name.to_string(),
            median_us: stats::median(&per_iter_us),
            mean_us: stats::mean(&per_iter_us),
            p10_us: stats::percentile(&per_iter_us, 10.0),
            p90_us: stats::percentile(&per_iter_us, 90.0),
            iters_per_sample: iters,
            samples: self.samples,
        };
        res.print();
        res
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher {
            sample_target_s: 0.002,
            samples: 3,
            warmup_s: 0.001,
        };
        let r = b.run("noop_sum", || (0..100u64).sum::<u64>());
        assert!(r.median_us > 0.0);
        assert!(r.p90_us >= r.p10_us);
        assert_eq!(r.samples, 3);
    }

    #[test]
    fn slower_work_measures_slower() {
        let b = Bencher {
            sample_target_s: 0.002,
            samples: 3,
            warmup_s: 0.001,
        };
        let fast = b.run("fast", || (0..10u64).sum::<u64>());
        let slow = b.run("slow", || (0..100_000u64).sum::<u64>());
        assert!(slow.median_us > fast.median_us);
    }
}
