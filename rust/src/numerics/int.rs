//! Symmetric integer quantization (INT4 / INT8) — the element types used
//! by the paper's INT4 generalizability ablation (Table 6) and by the
//! Atom baseline's mixed-precision scheme (INT4 bulk + INT8 outliers).

/// Symmetric signed integer codec with `bits` total bits.
/// Range: [-(2^(bits-1)-1), 2^(bits-1)-1] (no -2^(bits-1), keeping the
/// grid symmetric as standard for weight/activation PTQ).
#[derive(Copy, Clone, Debug)]
pub struct IntCodec {
    pub bits: u32,
}

pub const INT4: IntCodec = IntCodec { bits: 4 };
pub const INT8: IntCodec = IntCodec { bits: 8 };

impl IntCodec {
    pub const fn qmax(self) -> i32 {
        (1 << (self.bits - 1)) - 1
    }

    /// Round-half-to-even integer quantization of x/scale, clamped.
    #[inline]
    pub fn quantize_code(self, x: f32, scale: f32) -> i32 {
        if scale == 0.0 {
            return 0;
        }
        let v = (x / scale) as f64;
        let r = round_half_even(v);
        (r as i32).clamp(-self.qmax(), self.qmax())
    }

    #[inline]
    pub fn dequantize(self, code: i32, scale: f32) -> f32 {
        code as f32 * scale
    }

    /// Fake-quantize (QDQ) one value given a scale.
    #[inline]
    pub fn qdq(self, x: f32, scale: f32) -> f32 {
        self.dequantize(self.quantize_code(x, scale), scale)
    }

    /// Per-group symmetric scale from the group's absolute maximum.
    #[inline]
    pub fn scale_for(self, amax: f32) -> f32 {
        if amax == 0.0 {
            0.0
        } else {
            amax / self.qmax() as f32
        }
    }
}

#[inline]
fn round_half_even(v: f64) -> f64 {
    let r = v.round();
    if (v - v.trunc()).abs() == 0.5 {
        // Ties: pick the even integer.
        let down = v.trunc();
        let up = down + v.signum();
        if (down as i64) % 2 == 0 {
            down
        } else {
            up
        }
    } else {
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qmax_values() {
        assert_eq!(INT4.qmax(), 7);
        assert_eq!(INT8.qmax(), 127);
    }

    #[test]
    fn symmetric_roundtrip() {
        let scale = INT4.scale_for(3.5);
        for code in -7..=7 {
            let v = INT4.dequantize(code, scale);
            assert_eq!(INT4.quantize_code(v, scale), code);
        }
    }

    #[test]
    fn clamps_out_of_range() {
        assert_eq!(INT4.quantize_code(100.0, 1.0), 7);
        assert_eq!(INT4.quantize_code(-100.0, 1.0), -7);
    }

    #[test]
    fn zero_scale_zero_code() {
        assert_eq!(INT4.quantize_code(1.0, 0.0), 0);
        assert_eq!(INT4.scale_for(0.0), 0.0);
    }

    #[test]
    fn ties_to_even() {
        // 0.5/1.0 = 0.5 → even 0; 1.5 → 2; 2.5 → 2
        assert_eq!(INT4.quantize_code(0.5, 1.0), 0);
        assert_eq!(INT4.quantize_code(1.5, 1.0), 2);
        assert_eq!(INT4.quantize_code(2.5, 1.0), 2);
        assert_eq!(INT4.quantize_code(-1.5, 1.0), -2);
    }

    #[test]
    fn qdq_error_bounded() {
        let amax = 5.0f32;
        let scale = INT4.scale_for(amax);
        let mut x = -amax;
        while x <= amax {
            let e = (INT4.qdq(x, scale) - x).abs();
            assert!(e <= scale / 2.0 + 1e-6, "err {e} at {x}");
            x += 0.01;
        }
    }
}
