//! Generic minifloat codec: sign + `E` exponent bits + `M` mantissa bits.
//!
//! Encode is round-to-nearest-even onto the representable grid with
//! saturation at ±max_normal (Tensor-Core conversion semantics — no
//! inf/NaN are produced on overflow for the block-scaled formats). The
//! representable-value table per format is tiny (≤ 128 positive points),
//! so encoding is a branch-free binary search over precomputed midpoints,
//! which is bit-exact RNE because the grid is sorted and ties resolve to
//! the even (lower-LSB) code.

/// The element data types from the paper's Table 7.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum FpKind {
    /// FP4: 1-2-1, bias 1, max ±6 (NVFP4 / MXFP4 element)
    E2M1,
    /// FP6: 1-2-3, bias 1, max ±7.5
    E2M3,
    /// FP6: 1-3-2, bias 3, max ±28
    E3M2,
    /// FP8: 1-4-3, bias 7, max ±448 (MXFP8 element; NVFP4 block scale)
    E4M3,
    /// FP8: 1-5-2, bias 15, max ±57344 (the paper's outlier-threshold
    /// reference format)
    E5M2,
}

pub const E2M1: FpKind = FpKind::E2M1;
pub const E2M3: FpKind = FpKind::E2M3;
pub const E3M2: FpKind = FpKind::E3M2;
pub const E4M3: FpKind = FpKind::E4M3;
pub const E5M2: FpKind = FpKind::E5M2;

impl FpKind {
    pub const fn exp_bits(self) -> u32 {
        match self {
            FpKind::E2M1 | FpKind::E2M3 => 2,
            FpKind::E3M2 => 3,
            FpKind::E4M3 => 4,
            FpKind::E5M2 => 5,
        }
    }

    pub const fn man_bits(self) -> u32 {
        match self {
            FpKind::E2M1 => 1,
            FpKind::E3M2 | FpKind::E5M2 => 2,
            FpKind::E2M3 | FpKind::E4M3 => 3,
        }
    }

    pub const fn bias(self) -> i32 {
        match self {
            FpKind::E2M1 | FpKind::E2M3 => 1,
            FpKind::E3M2 => 3,
            FpKind::E4M3 => 7,
            FpKind::E5M2 => 15,
        }
    }

    /// Largest finite magnitude (paper Table 7 "Max Normal").
    pub const fn max_normal(self) -> f32 {
        match self {
            FpKind::E2M1 => 6.0,
            FpKind::E2M3 => 7.5,
            FpKind::E3M2 => 28.0,
            FpKind::E4M3 => 448.0,
            FpKind::E5M2 => 57344.0,
        }
    }

    /// Machine epsilon of the format: ulp(1.0)/2 = 2^-(M+1). The paper's
    /// §3.4 uses ε₄ = 2⁻² (E2M1) and ε₈ = 2⁻⁴ (E4M3).
    pub const fn eps(self) -> f32 {
        match self.man_bits() {
            1 => 0.25,    // 2^-2
            2 => 0.125,   // 2^-3
            3 => 0.0625,  // 2^-4
            _ => unreachable!(),
        }
    }

    /// Total storage bits including sign.
    pub const fn bits(self) -> u32 {
        1 + self.exp_bits() + self.man_bits()
    }

    /// Number of non-negative representable values (0 .. max_normal).
    fn n_pos(self) -> usize {
        // For E4M3, code S.1111.111 is NaN, so the top mantissa code of the
        // top exponent is excluded; for E5M2, exponent 11111 encodes
        // inf/NaN and is excluded entirely. FP4/FP6 have no inf/NaN.
        let full = 1usize << (self.exp_bits() + self.man_bits());
        match self {
            FpKind::E4M3 => full - 1,
            FpKind::E5M2 => full - (1 << self.man_bits()),
            _ => full,
        }
    }
}

/// Precomputed codec tables for one format.
#[derive(Clone, Debug)]
pub struct Minifloat {
    pub kind: FpKind,
    /// Positive representable magnitudes, ascending; `values[0] == 0`.
    values: Vec<f32>,
    /// `midpoints[i]` is the RNE decision boundary between `values[i]` and
    /// `values[i+1]`: `x <= midpoints[i]` rounds down iff tie goes to even i.
    midpoints: Vec<f32>,
    /// `tie_down[i]`: on exact tie at `midpoints[i]`, round to `values[i]`
    /// (true when code i is even).
    tie_down: Vec<bool>,
}

impl Minifloat {
    pub fn new(kind: FpKind) -> Self {
        let m = kind.man_bits();
        let bias = kind.bias();
        let n = kind.n_pos();
        let mut values = Vec::with_capacity(n);
        for code in 0..n as u32 {
            let exp_field = code >> m;
            let man_field = code & ((1 << m) - 1);
            let v = if exp_field == 0 {
                // subnormal: m/2^M * 2^(1-bias)
                (man_field as f32 / (1u32 << m) as f32) * 2f32.powi(1 - bias)
            } else {
                (1.0 + man_field as f32 / (1u32 << m) as f32)
                    * 2f32.powi(exp_field as i32 - bias)
            };
            values.push(v);
        }
        debug_assert!((values[n - 1] - kind.max_normal()).abs() < 1e-6 * kind.max_normal().max(1.0));
        let mut midpoints = Vec::with_capacity(n - 1);
        let mut tie_down = Vec::with_capacity(n - 1);
        for i in 0..n - 1 {
            // f64 midpoint avoids double-rounding on coarse grids.
            midpoints.push(((values[i] as f64 + values[i + 1] as f64) / 2.0) as f32);
            tie_down.push(i % 2 == 0);
        }
        Minifloat {
            kind,
            values,
            midpoints,
            tie_down,
        }
    }

    /// All positive representable magnitudes (ascending, starts at 0).
    pub fn grid(&self) -> &[f32] {
        &self.values
    }

    /// Quantize: snap to nearest representable value (RNE), saturating.
    /// Returns the *dequantized* value; see [`Minifloat::encode`] for codes.
    #[inline]
    pub fn quantize(&self, x: f32) -> f32 {
        let (mag, _) = self.quantize_mag(x.abs());
        if x.is_sign_negative() {
            -mag
        } else {
            mag
        }
    }

    /// Encode to (code, sign) where code indexes the positive grid.
    #[inline]
    pub fn encode(&self, x: f32) -> (u8, bool) {
        let (_, code) = self.quantize_mag(x.abs());
        (code, x.is_sign_negative())
    }

    /// Decode a (code, sign) pair.
    #[inline]
    pub fn decode(&self, code: u8, neg: bool) -> f32 {
        let v = self.values[code as usize];
        if neg {
            -v
        } else {
            v
        }
    }

    #[inline]
    fn quantize_mag(&self, a: f32) -> (f32, u8) {
        if a.is_nan() {
            return (0.0, 0);
        }
        let n = self.values.len();
        if a >= self.values[n - 1] {
            return (self.values[n - 1], (n - 1) as u8); // saturate
        }
        // Binary search over midpoints: find first midpoint >= a.
        let mut lo = 0usize;
        let mut hi = self.midpoints.len(); // == n-1
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.midpoints[mid] < a {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        // lo == index of first midpoint >= a; candidate codes lo, lo+1.
        if lo < self.midpoints.len() && a == self.midpoints[lo] && !self.tie_down[lo] {
            return (self.values[lo + 1], (lo + 1) as u8);
        }
        if lo < self.midpoints.len() && a > self.midpoints[lo] {
            return (self.values[lo + 1], (lo + 1) as u8);
        }
        (self.values[lo], lo as u8)
    }

    /// Smallest representable value y on the grid with y >= x
    /// (saturates at max_normal). Used for ceil-rounded scales, which keep
    /// the paper's α = s/M ≥ 1 alignment-overhead model.
    pub fn round_up(&self, x: f32) -> f32 {
        debug_assert!(x >= 0.0);
        let n = self.values.len();
        if x > self.values[n - 1] {
            return self.values[n - 1];
        }
        let idx = self.values.partition_point(|&v| v < x);
        self.values[idx.min(n - 1)]
    }
}

use std::sync::OnceLock;

/// Global codec cache — formats are tiny and immutable.
pub fn codec(kind: FpKind) -> &'static Minifloat {
    static CACHE: OnceLock<[Minifloat; 5]> = OnceLock::new();
    let all = CACHE.get_or_init(|| {
        [
            Minifloat::new(FpKind::E2M1),
            Minifloat::new(FpKind::E2M3),
            Minifloat::new(FpKind::E3M2),
            Minifloat::new(FpKind::E4M3),
            Minifloat::new(FpKind::E5M2),
        ]
    });
    match kind {
        FpKind::E2M1 => &all[0],
        FpKind::E2M3 => &all[1],
        FpKind::E3M2 => &all[2],
        FpKind::E4M3 => &all[3],
        FpKind::E5M2 => &all[4],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2m1_grid_matches_spec() {
        // The canonical FP4 value set.
        let c = codec(FpKind::E2M1);
        assert_eq!(c.grid(), &[0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]);
    }

    #[test]
    fn table7_max_normals() {
        assert_eq!(codec(FpKind::E2M1).grid().last(), Some(&6.0));
        assert_eq!(codec(FpKind::E2M3).grid().last(), Some(&7.5));
        assert_eq!(codec(FpKind::E3M2).grid().last(), Some(&28.0));
        assert_eq!(codec(FpKind::E4M3).grid().last(), Some(&448.0));
        assert_eq!(codec(FpKind::E5M2).grid().last(), Some(&57344.0));
    }

    #[test]
    fn representable_values_fixed_points() {
        for kind in [FpKind::E2M1, FpKind::E2M3, FpKind::E3M2, FpKind::E4M3, FpKind::E5M2] {
            let c = codec(kind);
            for &v in c.grid() {
                assert_eq!(c.quantize(v), v, "{kind:?} value {v} not a fixed point");
                assert_eq!(c.quantize(-v), -v);
            }
        }
    }

    #[test]
    fn rne_ties_to_even() {
        let c = codec(FpKind::E2M1);
        // midpoint between 2.0 (code 4, even) and 3.0 (code 5, odd) is 2.5
        // → ties to even → 2.0
        assert_eq!(c.quantize(2.5), 2.0);
        // midpoint between 3.0 (code 5) and 4.0 (code 6, even) is 3.5 → 4.0
        assert_eq!(c.quantize(3.5), 4.0);
        // midpoint between 0.0 (code 0, even) and 0.5 is 0.25 → 0.0
        assert_eq!(c.quantize(0.25), 0.0);
        // 0.75 is midpoint of 0.5 (code1) / 1.0 (code2 even) → 1.0
        assert_eq!(c.quantize(0.75), 1.0);
    }

    #[test]
    fn saturation_not_inf() {
        let c = codec(FpKind::E4M3);
        assert_eq!(c.quantize(1e9), 448.0);
        assert_eq!(c.quantize(-1e9), -448.0);
        assert_eq!(c.quantize(f32::INFINITY), 448.0);
    }

    #[test]
    fn nan_maps_to_zero() {
        assert_eq!(codec(FpKind::E2M1).quantize(f32::NAN), 0.0);
    }

    #[test]
    fn encode_decode_roundtrip() {
        for kind in [FpKind::E2M1, FpKind::E4M3, FpKind::E5M2] {
            let c = codec(kind);
            for code in 0..c.grid().len() as u8 {
                for neg in [false, true] {
                    let v = c.decode(code, neg);
                    let (c2, n2) = c.encode(v);
                    assert_eq!((c2, n2 && v != 0.0), (code, neg && v != 0.0));
                }
            }
        }
    }

    #[test]
    fn error_bounded_by_half_ulp() {
        // |x - Q(x)| <= eps * 2^floor(log2(|x|)) for normal-range x,
        // i.e. relative error <= eps for |x| in [min_normal, max_normal].
        for kind in [FpKind::E2M1, FpKind::E4M3, FpKind::E5M2] {
            let c = codec(kind);
            let eps = kind.eps();
            let min_normal = 2f32.powi(1 - kind.bias());
            let mut x = min_normal;
            while x < kind.max_normal() {
                let q = c.quantize(x);
                let exp = x.log2().floor();
                let bound = eps * 2f32.powf(exp) * (1.0 + 1e-5);
                assert!(
                    (x - q).abs() <= bound,
                    "{kind:?}: |{x} - {q}| > {bound}"
                );
                x *= 1.37; // sample the range
            }
        }
    }

    #[test]
    fn e4m3_subnormal_step() {
        // E4M3 subnormal step = 2^-3 * 2^-6 = 2^-9
        let c = codec(FpKind::E4M3);
        let step = c.grid()[1];
        assert!((step - 2f32.powi(-9)).abs() < 1e-12);
    }

    #[test]
    fn round_up_is_ceiling() {
        let c = codec(FpKind::E2M1);
        assert_eq!(c.round_up(2.1), 3.0);
        assert_eq!(c.round_up(3.0), 3.0);
        assert_eq!(c.round_up(0.0), 0.0);
        assert_eq!(c.round_up(100.0), 6.0); // saturates
        // never rounds below input (except saturation)
        for i in 0..1000 {
            let x = i as f32 * 0.006;
            assert!(c.round_up(x) >= x.min(6.0) - 1e-6);
        }
    }

    #[test]
    fn eps_matches_paper() {
        // §3.4: ε₄ = 2⁻², ε₈ = 2⁻⁴, ε₄² = ε₈.
        assert_eq!(FpKind::E2M1.eps(), 0.25);
        assert_eq!(FpKind::E4M3.eps(), 0.0625);
        assert_eq!(FpKind::E2M1.eps() * FpKind::E2M1.eps(), FpKind::E4M3.eps());
    }

    #[test]
    fn grid_sizes() {
        assert_eq!(codec(FpKind::E2M1).grid().len(), 8);
        assert_eq!(codec(FpKind::E4M3).grid().len(), 127); // 128 - NaN code
        assert_eq!(codec(FpKind::E5M2).grid().len(), 124); // 128 - inf/NaN exp
    }

    #[test]
    fn monotone_quantization() {
        // Quantization must be monotone non-decreasing.
        for kind in [FpKind::E2M1, FpKind::E4M3] {
            let c = codec(kind);
            let mut prev = f32::NEG_INFINITY;
            let mut x = -kind.max_normal() * 1.2;
            while x < kind.max_normal() * 1.2 {
                let q = c.quantize(x);
                assert!(q >= prev, "{kind:?} non-monotone at {x}");
                prev = q;
                x += kind.max_normal() / 300.0;
            }
        }
    }
}
