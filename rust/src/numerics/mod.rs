//! Bit-exact low-precision numeric codecs.
//!
//! Implements every element and scale data type from the paper's
//! Appendix A (Table 7): E2M1 (FP4), E2M3/E3M2 (FP6), E4M3/E5M2 (FP8),
//! the exponent-only E8M0 block-scale type, and symmetric INT4 — all with
//! round-to-nearest-even and saturating overflow, matching Tensor-Core
//! conversion semantics. These codecs are the foundation the block-scaled
//! formats in [`crate::formats`] are built on.

pub mod e8m0;
pub mod int;
pub mod minifloat;

pub use e8m0::E8M0;
pub use int::{IntCodec, INT4, INT8};
pub use minifloat::{codec, FpKind, Minifloat, E2M1, E2M3, E3M2, E4M3, E5M2};
