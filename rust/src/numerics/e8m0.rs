//! E8M0 — the OCP Microscaling block-scale type: an 8-bit biased exponent
//! with no sign and no mantissa. A code `e` represents 2^(e-127);
//! code 255 is NaN (unused here — we saturate).
//!
//! Two rounding modes are provided:
//! * `ceil` — smallest power of two ≥ x. This keeps the scale alignment
//!   overhead α = s/M in [1, 2), exactly the paper's §3.4 MXFP8 model
//!   (sup α_mx = 2). Used by default for block scales.
//! * `floor` — the OCP-spec `floor(log2(amax)) - emax` convention is
//!   expressed by callers via `from_exp`.

#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct E8M0(pub u8);

pub const E8M0_MIN_EXP: i32 = -127;
pub const E8M0_MAX_EXP: i32 = 127;

impl E8M0 {
    /// Encode the smallest representable power of two ≥ x (x > 0).
    /// Saturates at 2^±127. x ≤ 0 encodes the minimum scale.
    pub fn ceil_from(x: f32) -> E8M0 {
        if !(x > 0.0) || !x.is_finite() {
            return E8M0::from_exp(E8M0_MIN_EXP);
        }
        let e = x.log2().ceil() as i32;
        // Guard against log2 rounding: ensure 2^e >= x.
        let mut e = e;
        while 2f32.powi(e.min(E8M0_MAX_EXP)) < x && e < E8M0_MAX_EXP {
            e += 1;
        }
        E8M0::from_exp(e)
    }

    /// Encode from an explicit exponent (clamped to the representable range).
    pub fn from_exp(e: i32) -> E8M0 {
        let e = e.clamp(E8M0_MIN_EXP, E8M0_MAX_EXP);
        E8M0((e + 127) as u8)
    }

    pub fn exp(self) -> i32 {
        self.0 as i32 - 127
    }

    pub fn value(self) -> f32 {
        // 2^-127 underflows f32 normals but is fine as subnormal;
        // use powi on f64 then narrow for exactness at the extremes.
        (2f64.powi(self.exp())) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_powers_fixed() {
        for e in [-10, -1, 0, 1, 10, 100] {
            let s = E8M0::ceil_from(2f32.powi(e));
            assert_eq!(s.exp(), e);
            assert_eq!(s.value(), 2f32.powi(e));
        }
    }

    #[test]
    fn ceil_rounds_up() {
        assert_eq!(E8M0::ceil_from(3.0).value(), 4.0);
        assert_eq!(E8M0::ceil_from(1.0001).value(), 2.0);
        assert_eq!(E8M0::ceil_from(0.75).value(), 1.0);
        // alignment overhead α = s/x ∈ [1, 2) — paper §3.4
        let mut x = 1e-6f32;
        while x < 1e6 {
            let a = E8M0::ceil_from(x).value() / x;
            assert!((1.0..2.0 + 1e-6).contains(&a), "α={a} at x={x}");
            x *= 1.618;
        }
    }

    #[test]
    fn degenerate_inputs() {
        assert_eq!(E8M0::ceil_from(0.0).exp(), -127);
        assert_eq!(E8M0::ceil_from(-5.0).exp(), -127);
        assert_eq!(E8M0::ceil_from(f32::NAN).exp(), -127);
        assert_eq!(E8M0::ceil_from(f32::INFINITY).exp(), -127);
    }

    #[test]
    fn saturation() {
        assert_eq!(E8M0::ceil_from(1e38).exp(), 127);
        assert_eq!(E8M0::from_exp(500).exp(), 127);
        assert_eq!(E8M0::from_exp(-500).exp(), -127);
    }

    #[test]
    fn code_roundtrip() {
        for code in 0..=254u8 {
            let s = E8M0(code);
            assert_eq!(E8M0::from_exp(s.exp()), s);
        }
    }
}
