//! Calibration pipeline (paper §3.2 offline phase + Appendix B.1).
//!
//! Runs calibration token windows through the FP32 engine in collect
//! mode, merges per-site per-channel absolute maxima, and serializes the
//! result (plus derived reorder/S plans) to JSON — the same schema the
//! Python AOT path writes, so either side can consume either file.
//! Timing is recorded for the Table 4 reproduction.

use crate::baselines::LayerCalib;
use crate::model::{Engine, EngineMode, ModelConfig, Weights};
use crate::quant::{LayerPlan, Permutation};
use crate::util::json::Json;
use crate::util::Timer;
use std::collections::BTreeMap;

/// Calibration outcome for a model.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub sites: BTreeMap<String, LayerCalib>,
    pub seconds: f64,
    pub windows: usize,
    pub window_len: usize,
}

/// Run calibration: `windows` windows of `window_len` tokens from the
/// calibration stream (mirrors the paper's 128 x 2048 setup, scaled).
pub fn run_calibration(
    cfg: &ModelConfig,
    weights: &Weights,
    stream: &[u16],
    windows: usize,
    window_len: usize,
) -> Result<Calibration, String> {
    let engine = Engine::new(cfg.clone(), weights.clone(), EngineMode::Fp32, None)?;
    let mut sites: BTreeMap<String, LayerCalib> = BTreeMap::new();
    let t = Timer::start();
    let stride = (stream.len().saturating_sub(window_len + 1)) / windows.max(1);
    for w in 0..windows {
        let start = (w * stride.max(1)) % stream.len().saturating_sub(window_len).max(1);
        let toks = &stream[start..start + window_len];
        engine.forward(toks, Some(&mut sites), None);
    }
    Ok(Calibration {
        sites,
        seconds: t.ms() / 1e3,
        windows,
        window_len,
    })
}

impl Calibration {
    /// Derive per-site plans with the τ = 2⁻³·M rule (Figure 7 data).
    pub fn plans(&self, fmt: crate::formats::Format, max_s: usize) -> BTreeMap<String, LayerPlan> {
        self.sites
            .iter()
            .map(|(name, c)| {
                (
                    name.clone(),
                    LayerPlan::from_calibration_capped(&c.col_absmax, fmt, max_s),
                )
            })
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let mut sites = Json::obj();
        for (name, c) in &self.sites {
            let mut site = Json::obj();
            let plan = LayerPlan::from_calibration(&c.col_absmax, crate::formats::Format::Nvfp4);
            site.set("col_absmax", Json::from_f32s(&c.col_absmax))
                .set("perm", Json::from_usizes(&plan.perm.idx))
                .set("s", Json::Num(plan.s as f64));
            sites.set(name, site);
        }
        let mut j = Json::obj();
        j.set("sites", sites)
            .set("calib_seconds", Json::Num(self.seconds))
            .set("windows", Json::Num(self.windows as f64))
            .set("window_len", Json::Num(self.window_len as f64));
        j
    }

    pub fn save(&self, path: &str) -> Result<(), String> {
        std::fs::write(path, self.to_json().dump()).map_err(|e| e.to_string())
    }

    /// Load calibration stats from either the Rust or the Python
    /// (`{model}.plans.json`) schema.
    pub fn load(path: &str) -> Result<Calibration, String> {
        let text = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        let j = Json::parse(&text)?;
        let sites_j = j.get("sites").ok_or("missing 'sites'")?;
        let mut sites = BTreeMap::new();
        if let Json::Obj(m) = sites_j {
            for (name, site) in m {
                let absmax = site
                    .get("col_absmax")
                    .and_then(|v| v.to_f32s())
                    .ok_or_else(|| format!("{name}: missing col_absmax"))?;
                sites.insert(
                    name.clone(),
                    LayerCalib { col_absmax: absmax, sample: None },
                );
            }
        }
        Ok(Calibration {
            sites,
            seconds: j
                .get("calib_seconds")
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0),
            windows: j.get("windows").and_then(|v| v.as_usize()).unwrap_or(0),
            window_len: j
                .get("window_len")
                .and_then(|v| v.as_usize())
                .unwrap_or(0),
        })
    }

    /// Per-layer S values in layer order for a site kind (Figure 7).
    pub fn s_series(&self, kind: &str, fmt: crate::formats::Format, max_s: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut i = 0;
        loop {
            let name = format!("layers.{i}.{kind}");
            match self.sites.get(&name) {
                Some(c) => {
                    let perm = Permutation::sort_desc(&c.col_absmax);
                    let sel = crate::quant::select_outliers(&c.col_absmax, &perm, fmt.group());
                    out.push(sel.s.min(max_s));
                    i += 1;
                }
                None => break,
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Format;

    fn setup() -> (ModelConfig, Weights, Vec<u16>) {
        let cfg = ModelConfig::tiny_test();
        let weights = Weights::synthetic(&cfg, 5);
        let stream: Vec<u16> = (0..4000u32).map(|i| ((i * 37 + 11) % 256) as u16).collect();
        (cfg, weights, stream)
    }

    #[test]
    fn calibration_covers_all_sites() {
        let (cfg, w, stream) = setup();
        let c = run_calibration(&cfg, &w, &stream, 3, 32).unwrap();
        assert_eq!(c.sites.len(), cfg.l * 4);
        assert!(c.seconds > 0.0);
    }

    #[test]
    fn plans_have_aligned_s() {
        let (cfg, w, stream) = setup();
        let c = run_calibration(&cfg, &w, &stream, 2, 32).unwrap();
        let plans = c.plans(Format::Nvfp4, 512);
        for (name, p) in &plans {
            assert!(p.s % 16 == 0 || p.s == p.perm.len(), "{name}: s={}", p.s);
            assert!(p.perm.is_valid());
        }
    }

    #[test]
    fn json_roundtrip() {
        let (cfg, w, stream) = setup();
        let c = run_calibration(&cfg, &w, &stream, 2, 32).unwrap();
        let dir = std::env::temp_dir().join("arcquant_calib_test.json");
        let path = dir.to_str().unwrap();
        c.save(path).unwrap();
        let back = Calibration::load(path).unwrap();
        assert_eq!(back.sites.len(), c.sites.len());
        for (name, lc) in &c.sites {
            assert_eq!(back.sites[name].col_absmax, lc.col_absmax);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn s_series_per_layer() {
        let (cfg, w, stream) = setup();
        let c = run_calibration(&cfg, &w, &stream, 2, 32).unwrap();
        let series = c.s_series("attn_in", Format::Nvfp4, 512);
        assert_eq!(series.len(), cfg.l);
    }
}
