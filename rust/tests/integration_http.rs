//! Integration: the networked HTTP serving frontend, end to end over
//! real sockets — concurrent clients batched into shared decode ticks
//! with responses bit-exact against the single-sequence reference decode
//! loop, the streaming protocol, malformed/oversized-request rejection,
//! and OutOfPages/queue backpressure (429/503).

use arcquant::baselines::Method;
use arcquant::coordinator::{
    session_rng, HttpClient, HttpServeConfig, HttpServer, Metrics, Variant,
};
use arcquant::formats::{Format, KvFormat};
use arcquant::model::{tiny_test_fixture, Engine, EngineMode, KvCache, Sampler};
use arcquant::util::json::Json;

/// Tiny fp32 + QDQ + packed engines over one synthetic calibration —
/// built from the shared [`tiny_test_fixture`], the same construction
/// the CLI's `tiny-test` model uses, so server engines and reference
/// engines share numerics by construction.
fn gen_engines() -> Vec<(Variant, Engine)> {
    let (cfg, weights, coll) = tiny_test_fixture(3, 64);
    let method = Method::ArcQuant { fmt: Format::Nvfp4, max_s: Some(64) };
    let fp =
        Engine::new(cfg.clone(), weights.clone(), EngineMode::Fp32, None).unwrap();
    let qdq = Engine::new(
        cfg.clone(),
        weights.clone(),
        EngineMode::Quantized(method.clone()),
        Some(&coll),
    )
    .unwrap();
    let packed = Engine::new(
        cfg,
        weights,
        EngineMode::QuantizedPacked(method),
        Some(&coll),
    )
    .unwrap();
    vec![
        (Variant::Fp32, fp),
        (Variant::ArcQuant, qdq),
        (Variant::ArcPacked, packed),
    ]
}

/// Reference engines for replay (same construction = same numerics).
fn ref_engine(variant: Variant) -> Engine {
    gen_engines()
        .into_iter()
        .find(|(v, _)| *v == variant)
        .map(|(_, e)| e)
        .unwrap()
}

fn prompt_for(i: usize, len: usize) -> Vec<u16> {
    (0..len).map(|k| ((k * 37 + i * 91 + 11) % 256) as u16).collect()
}

/// Request body via the real client-side builder — the tests must speak
/// exactly the wire shape `loadgen` speaks.
fn body_for(prompt: &[u16], max_new: usize, variant: Variant, stream: bool) -> String {
    arcquant::coordinator::loadgen::loadgen_body(prompt, max_new, Some(variant), stream)
}

fn tokens_of(body: &str) -> Vec<u16> {
    let j = Json::parse(body).unwrap_or_else(|e| panic!("bad body {body:?}: {e}"));
    j.get("tokens")
        .and_then(|t| t.as_arr())
        .unwrap_or_else(|| panic!("no tokens in {body}"))
        .iter()
        .map(|t| t.as_f64().unwrap() as u16)
        .collect()
}

/// Greedy single-sequence reference replay: prefill + decode_step loop,
/// exactly what the served tokens must be bit-equal to.
fn reference_tokens(
    engine: &Engine,
    prompt: &[u16],
    max_new: usize,
    kv: KvFormat,
    seed: u64,
    id: u64,
) -> Vec<u16> {
    let sampler = Sampler::Greedy;
    let mut rng = session_rng(seed, id);
    let mut cache = KvCache::with_format(&engine.cfg, prompt.len() + max_new, kv);
    let mut tok = sampler.sample(&engine.prefill(prompt, &mut cache).unwrap(), &mut rng);
    let mut out = vec![tok];
    for _ in 1..max_new {
        tok = sampler.sample(&engine.decode_step(tok, &mut cache).unwrap(), &mut rng);
        out.push(tok);
    }
    out
}

/// Pull a metric value out of the Prometheus text rendering.
fn metric_value(metrics_text: &str, name: &str) -> f64 {
    metrics_text
        .lines()
        .find(|l| l.starts_with(name) && !l.starts_with('#'))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("metric {name} not found in:\n{metrics_text}"))
}

#[test]
fn eight_concurrent_clients_share_decode_ticks_bit_exact() {
    // ≥8 concurrent POST /v1/generate clients on one variant, one
    // max_decode_batch=8 server: every response must be bit-exact to the
    // reference decode loop, and the tick counters must prove the
    // clients were served from *shared* batched decode ticks.
    const CLIENTS: usize = 8;
    const PROMPT: usize = 24;
    const MAX_NEW: usize = 16;
    let cfg = HttpServeConfig {
        max_decode_batch: CLIENTS,
        kv_pages: 256,
        ..Default::default()
    };
    let server = HttpServer::start(cfg, "127.0.0.1:0", gen_engines()).unwrap();
    let addr = server.addr().to_string();

    // all clients connect first, then fire together — the scheduler's
    // intake loop sweeps them into the same running batch
    let barrier = std::sync::Barrier::new(CLIENTS);
    let results: Vec<(Vec<u16>, Vec<u16>, u64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|i| {
                let addr = addr.clone();
                let barrier = &barrier;
                scope.spawn(move || {
                    let mut cli = HttpClient::connect(&addr).unwrap();
                    let prompt = prompt_for(i, PROMPT);
                    let body = body_for(&prompt, MAX_NEW, Variant::ArcPacked, false);
                    barrier.wait();
                    let reply = cli
                        .request("POST", "/v1/generate", Some(&body))
                        .unwrap();
                    assert_eq!(reply.status, 200, "client {i}: {}", reply.body);
                    let j = Json::parse(&reply.body).unwrap();
                    let id = j.get("id").unwrap().as_f64().unwrap() as u64;
                    assert_eq!(j.get("finish").unwrap().as_str(), Some("length"));
                    (prompt, tokens_of(&reply.body), id)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // bit-exactness: each served generation equals its reference replay
    let engine = ref_engine(Variant::ArcPacked);
    for (prompt, served, id) in &results {
        assert_eq!(served.len(), MAX_NEW);
        let want =
            reference_tokens(&engine, prompt, MAX_NEW, KvFormat::Fp32, 0, *id);
        assert_eq!(served, &want, "served generation diverged (id {id})");
    }

    // shared ticks: 8 sequences produced 8*(MAX_NEW-1) decode tokens; if
    // each client had been served alone that would need 8*(MAX_NEW-1)
    // ticks. Batching must have packed them substantially tighter.
    let mut cli = HttpClient::connect(&addr).unwrap();
    let m = cli.request("GET", "/metrics", None).unwrap();
    assert_eq!(m.status, 200);
    let ticks = metric_value(&m.body, "arcquant_decode_ticks_total");
    let toks = metric_value(&m.body, "arcquant_decode_tokens_total");
    assert_eq!(toks as usize, CLIENTS * (MAX_NEW - 1));
    let mean_batch = toks / ticks;
    assert!(
        mean_batch > 1.5,
        "decode ticks were not shared: {toks} tokens over {ticks} ticks"
    );
    let completed = metric_value(&m.body, "arcquant_requests_completed_total");
    assert_eq!(completed as usize, CLIENTS);
    drop(cli);
    server.shutdown();
}

#[test]
fn every_exec_path_served_bit_exact_over_http() {
    // 3 concurrent clients per variant (fp32 / QDQ arcquant / packed):
    // the full ExecPath matrix over one server, all bit-exact.
    const PER_VARIANT: usize = 3;
    const PROMPT: usize = 16;
    const MAX_NEW: usize = 8;
    let variants = [Variant::Fp32, Variant::ArcQuant, Variant::ArcPacked];
    let server =
        HttpServer::start(HttpServeConfig::default(), "127.0.0.1:0", gen_engines())
            .unwrap();
    let addr = server.addr().to_string();

    let results: Vec<(Variant, Vec<u16>, Vec<u16>, u64)> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..PER_VARIANT * variants.len())
                .map(|i| {
                    let addr = addr.clone();
                    let variant = variants[i % variants.len()];
                    scope.spawn(move || {
                        let mut cli = HttpClient::connect(&addr).unwrap();
                        let prompt = prompt_for(i, PROMPT);
                        let body = body_for(&prompt, MAX_NEW, variant, false);
                        let reply = cli
                            .request("POST", "/v1/generate", Some(&body))
                            .unwrap();
                        assert_eq!(reply.status, 200, "{}", reply.body);
                        let j = Json::parse(&reply.body).unwrap();
                        assert_eq!(
                            j.get("variant").unwrap().as_str(),
                            Some(variant.artifact_key())
                        );
                        let id = j.get("id").unwrap().as_f64().unwrap() as u64;
                        (variant, prompt, tokens_of(&reply.body), id)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
    server.shutdown();

    for variant in variants {
        let engine = ref_engine(variant);
        for (v, prompt, served, id) in &results {
            if *v != variant {
                continue;
            }
            let want =
                reference_tokens(&engine, prompt, MAX_NEW, KvFormat::Fp32, 0, *id);
            assert_eq!(
                served, &want,
                "{variant:?} served generation diverged (id {id})"
            );
        }
    }
}

#[test]
fn nvfp4_kv_pages_serve_bit_exact_over_http() {
    // The quantized-KV serving path over the network: responses must
    // replay bit-exactly against a reference loop over an NVFP4 cache.
    const CLIENTS: usize = 4;
    const PROMPT: usize = 24;
    const MAX_NEW: usize = 8;
    let cfg = HttpServeConfig {
        kv_format: KvFormat::Nvfp4,
        kv_pages: 8,
        ..Default::default()
    };
    let server = HttpServer::start(cfg, "127.0.0.1:0", gen_engines()).unwrap();
    let addr = server.addr().to_string();
    let results: Vec<(Vec<u16>, Vec<u16>, u64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|i| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let mut cli = HttpClient::connect(&addr).unwrap();
                    let prompt = prompt_for(i, PROMPT);
                    let body = body_for(&prompt, MAX_NEW, Variant::ArcPacked, false);
                    let reply =
                        cli.request("POST", "/v1/generate", Some(&body)).unwrap();
                    assert_eq!(reply.status, 200, "{}", reply.body);
                    let id = Json::parse(&reply.body)
                        .unwrap()
                        .get("id")
                        .unwrap()
                        .as_f64()
                        .unwrap() as u64;
                    (prompt, tokens_of(&reply.body), id)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    // page gauges reflect the quantized geometry (8 pages total)
    let mut cli = HttpClient::connect(&addr).unwrap();
    let m = cli.request("GET", "/metrics", None).unwrap();
    assert_eq!(metric_value(&m.body, "arcquant_kv_pages_total") as usize, 8);
    drop(cli);
    server.shutdown();

    let engine = ref_engine(Variant::ArcPacked);
    for (prompt, served, id) in &results {
        let want =
            reference_tokens(&engine, prompt, MAX_NEW, KvFormat::Nvfp4, 0, *id);
        assert_eq!(served, &want, "nvfp4-KV served generation diverged");
    }
}

#[test]
fn streaming_chunks_match_unary_response() {
    let server =
        HttpServer::start(HttpServeConfig::default(), "127.0.0.1:0", gen_engines())
            .unwrap();
    let addr = server.addr().to_string();
    let prompt = prompt_for(0, 16);
    const MAX_NEW: usize = 6;

    let mut cli = HttpClient::connect(&addr).unwrap();
    // unary first
    let unary = cli
        .request(
            "POST",
            "/v1/generate",
            Some(&body_for(&prompt, MAX_NEW, Variant::Fp32, false)),
        )
        .unwrap();
    assert_eq!(unary.status, 200);
    let unary_tokens = tokens_of(&unary.body);
    assert_eq!(unary_tokens.len(), MAX_NEW);

    // then streamed on the same keep-alive connection
    let streamed = cli
        .request(
            "POST",
            "/v1/generate",
            Some(&body_for(&prompt, MAX_NEW, Variant::Fp32, true)),
        )
        .unwrap();
    assert_eq!(streamed.status, 200);
    assert_eq!(
        streamed.header("transfer-encoding").map(str::to_ascii_lowercase),
        Some("chunked".to_string())
    );
    let chunks = streamed.chunks.as_ref().expect("chunked reply");
    // one chunk per token + the final summary chunk
    assert_eq!(chunks.len(), MAX_NEW + 1, "chunks: {chunks:?}");
    let stream_tokens: Vec<u16> = chunks[..MAX_NEW]
        .iter()
        .map(|c| {
            Json::parse(c.trim())
                .unwrap()
                .get("token")
                .unwrap()
                .as_f64()
                .unwrap() as u16
        })
        .collect();
    let done = Json::parse(chunks[MAX_NEW].trim()).unwrap();
    assert_eq!(done.get("done"), Some(&Json::Bool(true)));
    assert_eq!(done.get("finish").unwrap().as_str(), Some("length"));
    let final_tokens: Vec<u16> = done
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as u16)
        .collect();
    // greedy decode: identical prompt ⇒ identical tokens, streamed or not
    assert_eq!(stream_tokens, unary_tokens);
    assert_eq!(final_tokens, unary_tokens);
    drop(cli);
    server.shutdown();
}

#[test]
fn malformed_requests_get_400_and_unknown_routes_404() {
    let server =
        HttpServer::start(HttpServeConfig::default(), "127.0.0.1:0", gen_engines())
            .unwrap();
    let addr = server.addr().to_string();
    let mut cli = HttpClient::connect(&addr).unwrap();

    // valid-JSON protocol violations → 400 with an error body, and the
    // keep-alive connection stays usable afterwards
    for body in [
        r#"{"max_new_tokens":4}"#,                  // missing prompt
        r#"{"prompt":[]}"#,                          // empty prompt
        r#"{"prompt":[70000]}"#,                     // token outside vocab
        r#"{"prompt":[1.5]}"#,                       // fractional token
        r#"{"prompt":[1],"variant":"bogus"}"#,       // unknown variant
        r#"{"prompt":[1],"max_new_tokens":0}"#,      // zero budget
        r#"{"prompt":[1],"max_new_tokens":100000}"#, // budget above cap
        r#"{"prompt":[1],"stream":"yes"}"#,          // non-bool stream
        r#"{"prompt":[1],"wat":1}"#,                 // unknown field
        r#"[1,2,3]"#,                                // non-object body
        "[[[[[[[[[[[[[[[[[[[1]]]]]]]]]]]]]]]]]]]",   // nesting bomb
    ] {
        let reply = cli.request("POST", "/v1/generate", Some(body)).unwrap();
        assert_eq!(reply.status, 400, "body {body} -> {}", reply.body);
        assert!(
            Json::parse(&reply.body).unwrap().get("error").is_some(),
            "400 body carries an error message"
        );
    }

    // unknown route / wrong method
    let reply = cli.request("GET", "/nope", None).unwrap();
    assert_eq!(reply.status, 404);
    let reply = cli.request("GET", "/v1/generate", None).unwrap();
    assert_eq!(reply.status, 405);
    let reply = cli.request("POST", "/healthz", None).unwrap();
    assert_eq!(reply.status, 405);

    // healthz still fine on the same connection
    let reply = cli.request("GET", "/healthz", None).unwrap();
    assert_eq!(reply.status, 200);
    assert!(reply.body.contains("\"status\":\"ok\""));

    // syntactically broken JSON closes with 400 (fresh connection: the
    // server drops malformed-request connections)
    let mut cli2 = HttpClient::connect(&addr).unwrap();
    let reply = cli2.request("POST", "/v1/generate", Some("{nope")).unwrap();
    assert_eq!(reply.status, 400);
    drop(cli);
    drop(cli2);
    server.shutdown();
}

#[test]
fn oversized_bodies_get_413() {
    let cfg = HttpServeConfig {
        max_body_bytes: 256,
        ..Default::default()
    };
    let server = HttpServer::start(cfg, "127.0.0.1:0", gen_engines()).unwrap();
    let addr = server.addr().to_string();
    let mut cli = HttpClient::connect(&addr).unwrap();
    let big = format!(
        r#"{{"prompt":[{}]}}"#,
        (0..500).map(|_| "1").collect::<Vec<_>>().join(",")
    );
    assert!(big.len() > 256);
    let reply = cli.request("POST", "/v1/generate", Some(&big)).unwrap();
    assert_eq!(reply.status, 413, "{}", reply.body);
    drop(cli);
    server.shutdown();
}

#[test]
fn backpressure_maps_to_503_and_429() {
    // 503: a request whose worst case exceeds the entire page pool can
    // never run (1 page = 16 fp32 tokens; 24-token prompt needs 2).
    let cfg = HttpServeConfig {
        kv_pages: 1,
        ..Default::default()
    };
    let server = HttpServer::start(cfg, "127.0.0.1:0", gen_engines()).unwrap();
    let addr = server.addr().to_string();
    let mut cli = HttpClient::connect(&addr).unwrap();
    let body = body_for(&prompt_for(0, 24), 8, Variant::Fp32, false);
    let reply = cli.request("POST", "/v1/generate", Some(&body)).unwrap();
    assert_eq!(reply.status, 503, "{}", reply.body);
    assert!(reply.header("retry-after").is_some());
    drop(cli);
    server.shutdown();

    // 429: a zero-capacity scheduler queue sheds every request
    let cfg = HttpServeConfig {
        queue_cap: 0,
        ..Default::default()
    };
    let server = HttpServer::start(cfg, "127.0.0.1:0", gen_engines()).unwrap();
    let addr = server.addr().to_string();
    let mut cli = HttpClient::connect(&addr).unwrap();
    let body = body_for(&prompt_for(0, 8), 4, Variant::Fp32, false);
    let reply = cli.request("POST", "/v1/generate", Some(&body)).unwrap();
    assert_eq!(reply.status, 429, "{}", reply.body);
    assert!(reply.header("retry-after").is_some());

    // rejected — but the server stays healthy
    let reply = cli.request("GET", "/healthz", None).unwrap();
    assert_eq!(reply.status, 200);
    let m = cli.request("GET", "/metrics", None).unwrap();
    assert!(metric_value(&m.body, "arcquant_requests_rejected_total") >= 1.0);
    drop(cli);
    server.shutdown();
}

#[test]
fn missing_engine_variant_gets_503() {
    // server loaded with fp32 only; a packed request cannot be served
    let engines: Vec<(Variant, Engine)> = gen_engines()
        .into_iter()
        .filter(|(v, _)| *v == Variant::Fp32)
        .collect();
    let server =
        HttpServer::start(HttpServeConfig::default(), "127.0.0.1:0", engines)
            .unwrap();
    let addr = server.addr().to_string();
    let mut cli = HttpClient::connect(&addr).unwrap();
    let body = body_for(&prompt_for(0, 8), 4, Variant::ArcPacked, false);
    let reply = cli.request("POST", "/v1/generate", Some(&body)).unwrap();
    assert_eq!(reply.status, 503, "{}", reply.body);
    // the default variant (first engine) still serves
    let mut j = Json::obj();
    j.set(
        "prompt",
        Json::Arr(prompt_for(0, 8).iter().map(|&t| Json::Num(t as f64)).collect()),
    )
    .set("max_new_tokens", Json::Num(4.0));
    let reply = cli.request("POST", "/v1/generate", Some(&j.dump())).unwrap();
    assert_eq!(reply.status, 200, "{}", reply.body);
    let parsed = Json::parse(&reply.body).unwrap();
    assert_eq!(parsed.get("variant").unwrap().as_str(), Some("fp32"));
    drop(cli);
    server.shutdown();
}

#[test]
fn graceful_shutdown_drains_and_refuses_new_connections() {
    let server =
        HttpServer::start(HttpServeConfig::default(), "127.0.0.1:0", gen_engines())
            .unwrap();
    let addr = server.addr().to_string();
    let mut cli = HttpClient::connect(&addr).unwrap();
    let body = body_for(&prompt_for(0, 8), 4, Variant::Fp32, false);
    let reply = cli.request("POST", "/v1/generate", Some(&body)).unwrap();
    assert_eq!(reply.status, 200);
    drop(cli);
    server.shutdown(); // blocks until acceptor + scheduler exited
    // the listener is gone: connecting now fails (or is closed instantly
    // without serving). Either way no request can be made.
    match HttpClient::connect(&addr) {
        Err(_) => {}
        Ok(mut cli) => {
            assert!(cli.request("GET", "/healthz", None).is_err());
        }
    }
}

#[test]
fn shared_prefix_requests_hit_cache_and_match_sharing_off() {
    // Three sequential requests carrying the same 214-token system
    // prompt (= two full nvfp4 pages) + distinct tails. The first
    // donates its prefix pages to the content-addressed index; the
    // second and third must serve both prefix chunks from it — and all
    // three must stay bit-exact to the private reference replay AND to
    // a --no-prefix-share server, the "sharing never changes bytes"
    // acceptance bar.
    const MAX_NEW: usize = 4;
    const TAIL: usize = 12;
    let prefix = arcquant::coordinator::shared_prefix(214, 256, 0);
    let prompts: Vec<Vec<u16>> = (0..3)
        .map(|i| {
            let mut p = prefix.clone();
            p.extend(prompt_for(i, TAIL));
            p
        })
        .collect();

    let serve = |share: bool| -> Vec<(Vec<u16>, u64)> {
        let cfg = HttpServeConfig {
            kv_format: KvFormat::Nvfp4,
            kv_pages: 8,
            share_prefix: share,
            ..Default::default()
        };
        let server = HttpServer::start(cfg, "127.0.0.1:0", gen_engines()).unwrap();
        let addr = server.addr().to_string();
        let mut cli = HttpClient::connect(&addr).unwrap();
        let mut out = Vec::new();
        for prompt in &prompts {
            let body = body_for(prompt, MAX_NEW, Variant::ArcPacked, false);
            let reply = cli.request("POST", "/v1/generate", Some(&body)).unwrap();
            assert_eq!(reply.status, 200, "{}", reply.body);
            let j = Json::parse(&reply.body).unwrap();
            assert_eq!(j.get("finish").unwrap().as_str(), Some("length"));
            let id = j.get("id").unwrap().as_f64().unwrap() as u64;
            out.push((tokens_of(&reply.body), id));
        }
        let m = cli.request("GET", "/metrics", None).unwrap();
        assert_eq!(m.status, 200);
        let hits = metric_value(&m.body, "arcquant_prefix_cache_hits_total");
        let lookups = metric_value(&m.body, "arcquant_prefix_cache_lookups_total");
        let saved = metric_value(&m.body, "arcquant_kv_pages_saved_total");
        if share {
            // 2 matchable chunks per prompt; the 2nd and 3rd hit both
            assert_eq!(lookups, 6.0, "lookups:\n{}", m.body);
            assert_eq!(hits, 4.0, "hits:\n{}", m.body);
            assert_eq!(saved, 4.0, "pages saved:\n{}", m.body);
            assert!(
                metric_value(&m.body, "arcquant_prefix_cache_hit_rate") > 0.5
            );
            assert!(metric_value(&m.body, "arcquant_kv_shared_pages") >= 1.0);
        } else {
            assert_eq!(lookups, 0.0, "sharing off must not probe the index");
            assert_eq!(hits, 0.0);
        }
        drop(cli);
        server.shutdown();
        out
    };

    let shared = serve(true);
    let private = serve(false);
    let engine = ref_engine(Variant::ArcPacked);
    for (i, ((tok_on, id_on), (tok_off, _))) in
        shared.iter().zip(private.iter()).enumerate()
    {
        let want = reference_tokens(
            &engine,
            &prompts[i],
            MAX_NEW,
            KvFormat::Nvfp4,
            0,
            *id_on,
        );
        assert_eq!(tok_on, &want, "sharing-on diverged from reference ({i})");
        assert_eq!(tok_on, tok_off, "sharing on/off disagree on request {i}");
    }
}

#[test]
fn replica_tier_colocates_shared_prefix_and_spreads_distinct_prompts() {
    // 3-replica tier: two sessions carrying the same 214-token system
    // prompt must hash to the same home replica (the second serves its
    // prefix chunks from the first's cached pages), distinct prompts
    // must spread across replicas, and every response must stay
    // bit-exact to the single-sequence reference replay.
    const MAX_NEW: usize = 4;
    const TAIL: usize = 12;
    const DISTINCT: usize = 12;
    let cfg = HttpServeConfig {
        replicas: 3,
        kv_format: KvFormat::Nvfp4,
        kv_pages: 8,
        ..Default::default()
    };
    let server = HttpServer::start(cfg, "127.0.0.1:0", gen_engines()).unwrap();
    let addr = server.addr().to_string();
    let mut cli = HttpClient::connect(&addr).unwrap();

    let prefix = arcquant::coordinator::shared_prefix(214, 256, 0);
    let mut replay: Vec<(Vec<u16>, Vec<u16>, u64)> = Vec::new();
    let mut run = |prompt: Vec<u16>, cli: &mut HttpClient| {
        let body = body_for(&prompt, MAX_NEW, Variant::ArcPacked, false);
        let reply = cli.request("POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(reply.status, 200, "{}", reply.body);
        let j = Json::parse(&reply.body).unwrap();
        let id = j.get("id").unwrap().as_f64().unwrap() as u64;
        replay.push((prompt, tokens_of(&reply.body), id));
    };
    for i in 0..2 {
        let mut p = prefix.clone();
        p.extend(prompt_for(i, TAIL));
        run(p, &mut cli);
    }

    // co-location: both shared-prefix sessions landed on one replica —
    // it probed the index twice per admission (2 matchable chunks) and
    // the second session hit both; no other replica saw a lookup
    let probed: Vec<usize> = server
        .replica_metrics()
        .iter()
        .enumerate()
        .filter(|(_, m)| Metrics::get(&m.prefix_lookups) > 0)
        .map(|(r, _)| r)
        .collect();
    assert_eq!(probed.len(), 1, "prefix traffic on replicas {probed:?}");
    let home = &server.replica_metrics()[probed[0]];
    assert_eq!(Metrics::get(&home.prefix_lookups), 4);
    assert_eq!(Metrics::get(&home.prefix_hits), 2);
    assert_eq!(Metrics::get(&home.completed), 2);

    // spread: distinct prompts (no shared prefix) hash across replicas
    for i in 0..DISTINCT {
        run(prompt_for(i + 10, 16), &mut cli);
    }
    let serving = server
        .replica_metrics()
        .iter()
        .filter(|m| Metrics::get(&m.completed) > 0)
        .count();
    assert!(
        serving >= 2,
        "12 distinct prompts all routed to one replica of three"
    );

    drop(cli);
    server.shutdown();

    // bit-exactness across the whole tier, shared and distinct alike
    let engine = ref_engine(Variant::ArcPacked);
    for (prompt, served, id) in &replay {
        let want =
            reference_tokens(&engine, prompt, MAX_NEW, KvFormat::Nvfp4, 0, *id);
        assert_eq!(served, &want, "replica-tier generation diverged (id {id})");
    }
}

#[test]
fn metrics_catalog_renders_over_http() {
    let server =
        HttpServer::start(HttpServeConfig::default(), "127.0.0.1:0", gen_engines())
            .unwrap();
    let addr = server.addr().to_string();
    let mut cli = HttpClient::connect(&addr).unwrap();
    let body = body_for(&prompt_for(0, 8), 4, Variant::ArcPacked, false);
    let reply = cli.request("POST", "/v1/generate", Some(&body)).unwrap();
    assert_eq!(reply.status, 200);
    let m = cli.request("GET", "/metrics", None).unwrap();
    assert_eq!(m.status, 200);
    assert!(m
        .header("content-type")
        .is_some_and(|c| c.starts_with("text/plain")));
    for family in [
        "arcquant_requests_submitted_total",
        "arcquant_requests_completed_total",
        "arcquant_requests_rejected_total",
        "arcquant_decode_ticks_total",
        "arcquant_decode_tokens_total",
        "arcquant_generated_tokens_total",
        "arcquant_http_responses_total",
        "arcquant_queue_depth",
        "arcquant_kv_pages_used",
        "arcquant_kv_pages_total",
        "arcquant_request_latency_ms_bucket",
        "arcquant_request_latency_ms_sum",
        "arcquant_request_latency_ms_count",
        "arcquant_stage_ms_total",
    ] {
        assert!(m.body.contains(family), "metrics missing {family}");
    }
    // the served request shows up in the per-variant token counter
    assert!(metric_value(
        &m.body,
        "arcquant_generated_tokens_total{variant=\"arcquant-packed\"}"
    ) >= 4.0);
    assert!(metric_value(&m.body, "arcquant_request_latency_ms_count") >= 1.0);
    drop(cli);
    server.shutdown();
}
