//! Integration: fault-contained serving, end to end over real sockets —
//! injected scheduler panics answered as well-formed errors and contained
//! by a supervised in-process restart (post-recovery responses bit-exact
//! against the reference decode loop), request deadlines finishing as
//! `"timeout"`, streaming disconnects cancelling their session and
//! reclaiming KV pages, and the restart budget draining a crash loop
//! into 503s.

use arcquant::baselines::Method;
use arcquant::coordinator::{
    session_rng, shared_prefix, HttpClient, HttpServeConfig, HttpServer, Variant,
};
use arcquant::formats::{Format, KvFormat};
use arcquant::model::{tiny_test_fixture, Engine, EngineMode, KvCache, Sampler};
use arcquant::util::fault::Faults;
use arcquant::util::json::Json;
use std::io::{Read as _, Write as _};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Same tiny engine construction the other serving tests use, so server
/// engines and reference engines share numerics by construction.
fn gen_engines() -> Vec<(Variant, Engine)> {
    let (cfg, weights, coll) = tiny_test_fixture(3, 64);
    let method = Method::ArcQuant { fmt: Format::Nvfp4, max_s: Some(64) };
    let fp =
        Engine::new(cfg.clone(), weights.clone(), EngineMode::Fp32, None).unwrap();
    let packed = Engine::new(
        cfg,
        weights,
        EngineMode::QuantizedPacked(method),
        Some(&coll),
    )
    .unwrap();
    vec![(Variant::Fp32, fp), (Variant::ArcPacked, packed)]
}

fn ref_engine(variant: Variant) -> Engine {
    gen_engines()
        .into_iter()
        .find(|(v, _)| *v == variant)
        .map(|(_, e)| e)
        .unwrap()
}

fn prompt_for(i: usize, len: usize) -> Vec<u16> {
    (0..len).map(|k| ((k * 37 + i * 91 + 11) % 256) as u16).collect()
}

fn body_for(prompt: &[u16], max_new: usize, variant: Variant, stream: bool) -> String {
    arcquant::coordinator::loadgen::loadgen_body(prompt, max_new, Some(variant), stream)
}

/// `body_for` + an explicit `timeout_ms` field.
fn body_with_timeout(
    prompt: &[u16],
    max_new: usize,
    variant: Variant,
    timeout_ms: u64,
) -> String {
    let mut j = Json::parse(&body_for(prompt, max_new, variant, false)).unwrap();
    j.set("timeout_ms", Json::Num(timeout_ms as f64));
    j.dump()
}

fn tokens_of(j: &Json) -> Vec<u16> {
    j.get("tokens")
        .and_then(|t| t.as_arr())
        .unwrap_or_else(|| panic!("no tokens in {}", j.dump()))
        .iter()
        .map(|t| t.as_f64().unwrap() as u16)
        .collect()
}

/// Greedy single-sequence reference replay — what served tokens must be
/// bit-equal to, before and after a contained fault.
fn reference_tokens(
    engine: &Engine,
    prompt: &[u16],
    max_new: usize,
    kv: KvFormat,
    seed: u64,
    id: u64,
) -> Vec<u16> {
    let sampler = Sampler::Greedy;
    let mut rng = session_rng(seed, id);
    let mut cache = KvCache::with_format(&engine.cfg, prompt.len() + max_new, kv);
    let mut tok = sampler.sample(&engine.prefill(prompt, &mut cache).unwrap(), &mut rng);
    let mut out = vec![tok];
    for _ in 1..max_new {
        tok = sampler.sample(&engine.decode_step(tok, &mut cache).unwrap(), &mut rng);
        out.push(tok);
    }
    out
}

fn metric_value(metrics_text: &str, name: &str) -> f64 {
    metrics_text
        .lines()
        .find(|l| l.starts_with(name) && !l.starts_with('#'))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("metric {name} not found in:\n{metrics_text}"))
}

#[test]
fn injected_tick_panic_is_contained_and_recovery_is_bit_identical() {
    // The second batched decode forward panics (injected). The in-flight
    // streaming request must get a well-formed terminal error chunk, the
    // scheduler must restart in-process exactly once, and post-recovery
    // shared-prefix requests must replay bit-identically against the
    // single-sequence reference.
    const MAX_NEW: usize = 8;
    const TAIL: usize = 12;
    let cfg = HttpServeConfig {
        kv_format: KvFormat::Nvfp4,
        kv_pages: 8,
        faults: Faults::parse("tick_decode:2:panic").unwrap(),
        ..Default::default()
    };
    let server = HttpServer::start(cfg, "127.0.0.1:0", gen_engines()).unwrap();
    let addr = server.addr().to_string();
    // every prompt leads with the same 214-token system prompt (= two
    // full nvfp4 pages), the shape the prefix cache accelerates
    let prefix = shared_prefix(214, 256, 0);
    let prompts: Vec<Vec<u16>> = (0..3)
        .map(|i| {
            let mut p = prefix.clone();
            p.extend(prompt_for(i, TAIL));
            p
        })
        .collect();

    // request 1 streams; its session dies to the injected panic after
    // the prefill-sampled token and one decode tick
    let mut cli = HttpClient::connect(&addr).unwrap();
    let doomed = cli
        .request(
            "POST",
            "/v1/generate",
            Some(&body_for(&prompts[0], MAX_NEW, Variant::ArcPacked, true)),
        )
        .unwrap();
    assert_eq!(doomed.status, 200, "streaming had already committed a 200");
    let chunks = doomed.chunks.as_ref().expect("chunked reply");
    assert!(chunks.len() >= 2, "expected token chunk(s) + error chunk: {chunks:?}");
    let last = Json::parse(chunks.last().unwrap().trim()).unwrap();
    assert_eq!(last.get("done"), Some(&Json::Bool(true)));
    let err = last.get("error").and_then(|e| e.as_str()).unwrap_or_default();
    assert!(
        err.contains("scheduler fault"),
        "terminal chunk must carry the fault: {last:?}"
    );
    drop(cli); // the server closes faulted connections

    // requests 2 and 3 land on the rebuilt core: both bit-exact, and the
    // third serves its prefix out of the repopulated cache
    let engine = ref_engine(Variant::ArcPacked);
    let mut cli = HttpClient::connect(&addr).unwrap();
    for prompt in &prompts[1..] {
        let reply = cli
            .request(
                "POST",
                "/v1/generate",
                Some(&body_for(prompt, MAX_NEW, Variant::ArcPacked, false)),
            )
            .unwrap();
        assert_eq!(reply.status, 200, "post-recovery request failed: {}", reply.body);
        let j = Json::parse(&reply.body).unwrap();
        assert_eq!(j.get("finish").unwrap().as_str(), Some("length"));
        let id = j.get("id").unwrap().as_f64().unwrap() as u64;
        let want =
            reference_tokens(&engine, prompt, MAX_NEW, KvFormat::Nvfp4, 0, id);
        assert_eq!(
            tokens_of(&j),
            want,
            "post-recovery generation diverged (id {id})"
        );
    }

    let m = cli.request("GET", "/metrics", None).unwrap();
    assert_eq!(
        metric_value(&m.body, "arcquant_scheduler_restarts_total"),
        1.0,
        "exactly one supervised restart"
    );
    assert_eq!(
        metric_value(&m.body, "arcquant_sessions_failed_total{reason=\"panic\"}"),
        1.0
    );
    // the doomed session's pages were reclaimed on restart
    assert!(metric_value(&m.body, "arcquant_kv_pages_reclaimed_total") >= 1.0);
    // the rebuilt core repopulated the prefix cache: request 3 hit both
    // of its 107-token chunks
    assert!(
        metric_value(&m.body, "arcquant_prefix_cache_hits_total") >= 2.0,
        "post-recovery prefix sharing is dead:\n{}",
        m.body
    );
    let h = cli.request("GET", "/healthz", None).unwrap();
    assert_eq!(h.status, 200);
    drop(cli);
    server.shutdown();
}

#[test]
fn request_deadlines_finish_as_timeout_over_http() {
    // One server with a 1ms default deadline. An explicitly-zero budget
    // expires in the queue (empty tokens), the server default expires a
    // long generation mid-decode (partial tokens), and a generous
    // per-request override outlives both and finishes normally — the
    // request's own field always wins over the server default.
    let cfg = HttpServeConfig {
        request_timeout_ms: 1,
        ..Default::default()
    };
    let server = HttpServer::start(cfg, "127.0.0.1:0", gen_engines()).unwrap();
    let addr = server.addr().to_string();
    let mut cli = HttpClient::connect(&addr).unwrap();
    let prompt = prompt_for(0, 32);

    // timeout_ms: 0 — already expired at admission
    let reply = cli
        .request(
            "POST",
            "/v1/generate",
            Some(&body_with_timeout(&prompt, 8, Variant::Fp32, 0)),
        )
        .unwrap();
    assert_eq!(reply.status, 200, "{}", reply.body);
    let j = Json::parse(&reply.body).unwrap();
    assert_eq!(j.get("finish").unwrap().as_str(), Some("timeout"));
    assert!(tokens_of(&j).is_empty(), "never ran: no tokens");

    // no field — the server's 1ms default reaps this 256-token decode
    // mid-flight with whatever it had (still a 200: truncation)
    const BIG: usize = 256;
    let reply = cli
        .request(
            "POST",
            "/v1/generate",
            Some(&body_for(&prompt, BIG, Variant::Fp32, false)),
        )
        .unwrap();
    assert_eq!(reply.status, 200, "{}", reply.body);
    let j = Json::parse(&reply.body).unwrap();
    assert_eq!(j.get("finish").unwrap().as_str(), Some("timeout"));
    assert!(
        tokens_of(&j).len() < BIG,
        "a 1ms budget cannot fund {BIG} decode ticks"
    );

    // a generous override wins over the server default and runs to length
    let reply = cli
        .request(
            "POST",
            "/v1/generate",
            Some(&body_with_timeout(&prompt, 8, Variant::Fp32, 60_000)),
        )
        .unwrap();
    assert_eq!(reply.status, 200, "{}", reply.body);
    let j = Json::parse(&reply.body).unwrap();
    assert_eq!(j.get("finish").unwrap().as_str(), Some("length"));
    let id = j.get("id").unwrap().as_f64().unwrap() as u64;
    let engine = ref_engine(Variant::Fp32);
    assert_eq!(
        tokens_of(&j),
        reference_tokens(&engine, &prompt, 8, KvFormat::Fp32, 0, id)
    );

    let m = cli.request("GET", "/metrics", None).unwrap();
    assert_eq!(
        metric_value(&m.body, "arcquant_sessions_failed_total{reason=\"timeout\"}"),
        2.0
    );
    drop(cli);
    server.shutdown();
}

#[test]
fn streaming_disconnect_cancels_session_and_reclaims_kv_pages() {
    // A streaming client that vanishes mid-generation: the failed socket
    // write sets the session's cancel flag, the next tick reaps it as a
    // disconnect, and its KV pages return to the pool — observed as a
    // metrics delta (sessions_failed{disconnect}, kv_pages_used back to
    // zero, kv_pages_reclaimed counted).
    let cfg = HttpServeConfig {
        share_prefix: false, // every page private ⇒ used must return to 0
        ..Default::default()
    };
    let server = HttpServer::start(cfg, "127.0.0.1:0", gen_engines()).unwrap();
    let addr = server.addr().to_string();

    // raw socket: fire a long streaming generation, read up to the first
    // token chunk, then vanish without reading the rest
    let body = body_for(&prompt_for(0, 16), 256, Variant::Fp32, true);
    let mut raw = TcpStream::connect(&addr).unwrap();
    raw.write_all(
        format!(
            "POST /v1/generate HTTP/1.1\r\nHost: arcquant\r\n\
             Content-Length: {}\r\nConnection: keep-alive\r\n\r\n",
            body.len()
        )
        .as_bytes(),
    )
    .unwrap();
    raw.write_all(body.as_bytes()).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut seen = Vec::new();
    let mut buf = [0u8; 256];
    while !String::from_utf8_lossy(&seen).contains("token") {
        let n = raw.read(&mut buf).expect("stream head");
        assert!(n > 0, "server closed the stream before the first token");
        seen.extend_from_slice(&buf[..n]);
    }
    drop(raw); // unread buffered chunks ⇒ RST ⇒ the server's writes fail

    // the reap is asynchronous (next tick after the failed write): poll
    // the metrics endpoint briefly instead of assuming scheduling order
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let mut cli = HttpClient::connect(&addr).unwrap();
        let m = cli.request("GET", "/metrics", None).unwrap();
        let failed = metric_value(
            &m.body,
            "arcquant_sessions_failed_total{reason=\"disconnect\"}",
        );
        let used = metric_value(&m.body, "arcquant_kv_pages_used");
        if failed >= 1.0 && used == 0.0 {
            assert!(
                metric_value(&m.body, "arcquant_kv_pages_reclaimed_total") >= 1.0,
                "reclaimed pages must be counted:\n{}",
                m.body
            );
            // a disconnect is not a completion
            assert_eq!(
                metric_value(&m.body, "arcquant_requests_completed_total"),
                0.0
            );
            break;
        }
        assert!(
            Instant::now() < deadline,
            "disconnected session was not reaped: failed={failed} used={used}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    server.shutdown();
}

#[test]
fn restart_budget_exhaustion_drains_to_503() {
    // Two plans with nth=1 on the decode site: the first decode forward
    // after each rebuild panics again — a crash loop. With a budget of
    // one restart per window, the second restart flips the server into
    // draining: every subsequent request is shed as 503 while /healthz
    // stays up (fail loudly, never flap).
    let cfg = HttpServeConfig {
        faults: Faults::parse("tick_decode:1,tick_decode:1").unwrap(),
        restart_budget: 1,
        ..Default::default()
    };
    let server = HttpServer::start(cfg, "127.0.0.1:0", gen_engines()).unwrap();
    let addr = server.addr().to_string();
    let body = body_for(&prompt_for(0, 8), 4, Variant::Fp32, false);

    for round in 0..2 {
        // unary: the contained panic surfaces as a clean 500
        let mut cli = HttpClient::connect(&addr).unwrap();
        let reply = cli.request("POST", "/v1/generate", Some(&body)).unwrap();
        assert_eq!(reply.status, 500, "round {round}: {}", reply.body);
        let j = Json::parse(&reply.body).unwrap();
        assert!(j
            .get("error")
            .and_then(|e| e.as_str())
            .is_some_and(|e| e.contains("scheduler fault")));
        drop(cli); // 500s close the connection
    }

    // budget blown: the server drains instead of flapping
    let mut cli = HttpClient::connect(&addr).unwrap();
    let reply = cli.request("POST", "/v1/generate", Some(&body)).unwrap();
    assert_eq!(reply.status, 503, "draining server must shed load");
    assert!(reply.body.contains("shutting down"), "{}", reply.body);
    let h = cli.request("GET", "/healthz", None).unwrap();
    assert_eq!(h.status, 200, "health stays observable while draining");
    let m = cli.request("GET", "/metrics", None).unwrap();
    assert_eq!(metric_value(&m.body, "arcquant_scheduler_restarts_total"), 2.0);
    assert_eq!(
        metric_value(&m.body, "arcquant_sessions_failed_total{reason=\"panic\"}"),
        2.0
    );
    drop(cli);
    server.shutdown();
}
