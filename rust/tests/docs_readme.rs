//! Documentation drift guards: the README CLI reference must cover every
//! subcommand `rust/src/main.rs` actually dispatches, and the docs index
//! must only point at files that exist. These are the tests that keep the
//! documentation system honest — a new subcommand (or a renamed doc)
//! fails CI until the docs catch up.

use std::fs;
use std::path::Path;

fn repo_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn read(rel: &str) -> String {
    let p = repo_root().join(rel);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

/// Subcommand names dispatched in main(): every `Some("name") =>` arm of
/// the top-level match.
fn dispatched_subcommands(main_src: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in main_src.lines() {
        let t = line.trim();
        let Some(rest) = t.strip_prefix("Some(\"") else { continue };
        let Some(end) = rest.find('"') else { continue };
        // only dispatch arms (`Some("x") => cmd_...`), not flag parsing
        if rest[end..].contains("=> cmd_") {
            out.push(rest[..end].to_string());
        }
    }
    out
}

#[test]
fn readme_covers_every_subcommand() {
    let main_src = read("rust/src/main.rs");
    let readme = read("README.md");
    let subs = dispatched_subcommands(&main_src);
    assert!(
        subs.len() >= 6,
        "expected ≥6 dispatched subcommands in main.rs, found {subs:?}"
    );
    for sub in &subs {
        assert!(
            readme.contains(&format!("### `{sub}`")),
            "README.md CLI reference is missing a section for subcommand \
             `{sub}` (add a `### \\`{sub}\\`` heading)"
        );
    }
    // the help text must know about them too — search only the USAGE
    // block (checking the whole file would be tautological: the dispatch
    // arm the name came from contains it by construction)
    let usage_start = main_src.find("USAGE:").expect("main.rs help has a USAGE block");
    let usage = &main_src[usage_start..];
    // the help string literal ends where its println! argument begins
    let usage_end = usage.find("arcquant::VERSION").unwrap_or(usage.len());
    let usage = &usage[..usage_end];
    for sub in &subs {
        assert!(
            usage.contains(sub.as_str()),
            "help text (USAGE block) lost subcommand {sub}"
        );
    }
}

#[test]
fn readme_documents_the_kv_format_flag() {
    let readme = read("README.md");
    assert!(readme.contains("--kv-format"), "README must document --kv-format");
    for fmt in ["fp32", "nvfp4", "mxfp4", "razer", "fouroversix"] {
        assert!(readme.contains(fmt), "README must name the {fmt} KV format");
    }
    // the CLI parse errors must advertise the same value lists
    let main_src = read("rust/src/main.rs");
    for fmt in ["razer", "fouroversix"] {
        assert!(
            main_src.contains(&format!("\"{fmt}\"")),
            "main.rs must parse the {fmt} format value"
        );
    }
}

#[test]
fn formats_doc_catalogs_every_registered_codec() {
    // the codec catalog cannot drift: every format the conformance
    // registry knows must appear in docs/formats.md by display name,
    // and the doc must carry the RaZeR/Four-over-Six specifics the
    // README points at.
    use arcquant::formats::conformance::registered_formats;
    let doc = read("docs/formats.md");
    for fmt in registered_formats() {
        assert!(
            doc.contains(fmt.name()),
            "docs/formats.md codec catalog is missing `{}`",
            fmt.name()
        );
    }
    for needle in [
        "redundant-zero",
        "+5.0",
        "amax/4",
        "amax/6",
        "path_for_encoding",
        "ElementEncoding",
        "conformance",
        "bytes/token",
    ] {
        assert!(doc.contains(needle), "docs/formats.md must cover {needle}");
    }
}

#[test]
fn readme_documents_the_simd_knob() {
    let readme = read("README.md");
    assert!(
        readme.contains("ARCQUANT_SIMD"),
        "README must document the ARCQUANT_SIMD dispatch override"
    );
    for value in ["auto", "avx2", "scalar"] {
        assert!(readme.contains(value), "README must name the {value} SIMD mode");
    }
    // and the design doc must carry the dispatch section the README
    // points into
    let doc = read("docs/packed_path.md");
    for needle in ["SIMD dispatch", "ARCQUANT_SIMD", "pshufb", "arcquant_simd_path"] {
        assert!(doc.contains(needle), "docs/packed_path.md must cover {needle}");
    }
}

#[test]
fn docs_index_links_resolve() {
    let index = read("docs/README.md");
    for doc in [
        "ARCHITECTURE.md",
        "packed_path.md",
        "decode_serving.md",
        "formats.md",
        "kv_cache.md",
        "http_serving.md",
    ] {
        assert!(index.contains(doc), "docs/README.md must link {doc}");
        assert!(
            repo_root().join("docs").join(doc).exists(),
            "docs/{doc} linked from the index but missing"
        );
    }
}

#[test]
fn readme_documents_the_http_frontend() {
    let readme = read("README.md");
    for needle in ["--http", "/v1/generate", "loadgen", "/healthz", "/metrics"] {
        assert!(
            readme.contains(needle),
            "README must document the HTTP frontend ({needle})"
        );
    }
}

#[test]
fn http_doc_covers_protocol_and_backpressure() {
    let doc = read("docs/http_serving.md");
    for needle in [
        "/v1/generate",
        "/healthz",
        "/metrics",
        "stream",
        "chunked",
        "429",
        "503",
        "400",
        "413",
        "Retry-After",
        "loadgen",
    ] {
        assert!(doc.contains(needle), "docs/http_serving.md must cover {needle}");
    }
}

#[test]
fn docs_cover_failure_semantics_and_fault_injection() {
    // The fault-containment surface is documented and cannot drift: the
    // README names the chaos knob and the new serving flags, and the
    // serving doc carries the failure-semantics contract (deadlines,
    // disconnects, supervised restarts, draining) plus the metric
    // families those paths export.
    let readme = read("README.md");
    for needle in ["ARCQUANT_FAULTS", "--request-timeout-ms", "--no-retry"] {
        assert!(readme.contains(needle), "README must document {needle}");
    }
    let doc = read("docs/http_serving.md");
    for needle in [
        "Failure semantics",
        "ARCQUANT_FAULTS",
        "timeout_ms",
        "\"timeout\"",
        "disconnect",
        "draining",
        "arcquant_scheduler_restarts_total",
        "arcquant_sessions_failed_total",
        "arcquant_kv_pages_reclaimed_total",
        "tick_decode",
        "Retry-After",
        "--no-retry",
    ] {
        assert!(doc.contains(needle), "docs/http_serving.md must cover {needle}");
    }
}

#[test]
fn docs_cover_the_replica_tier_and_open_loop_loadgen() {
    // The sharded serving tier and the open-loop goodput loadgen are
    // documented and cannot drift: the README names every new flag, and
    // the serving doc carries the routing policy, the per-replica
    // budget/restart story, the `replica` metric label and the
    // open-loop/goodput vocabulary the CI gate asserts on.
    let readme = read("README.md");
    for needle in [
        "--replicas",
        "--pages-per-replica",
        "--arrival poisson",
        "--rate",
        "--slo-ms",
        "LOADGEN_OPENLOOP",
    ] {
        assert!(readme.contains(needle), "README must document {needle}");
    }
    let doc = read("docs/http_serving.md");
    for needle in [
        "Replica tier",
        "--replicas",
        "--pages-per-replica",
        "rendezvous",
        "home replica",
        "least-loaded",
        "route_key",
        "{replica=\"i\"}",
        "--arrival poisson",
        "--rate",
        "--slo-ms",
        "goodput",
        "LOADGEN_OPENLOOP",
        "GATE http_goodput_open_loop",
        "replica_goodput_speedup",
    ] {
        assert!(doc.contains(needle), "docs/http_serving.md must cover {needle}");
    }
}

#[test]
fn http_doc_catalogs_every_exported_metric() {
    // the metrics catalog cannot drift: every family the server renders
    // must be documented (names are extracted from a live rendering)
    use arcquant::coordinator::Metrics;
    let m = Metrics::new();
    m.record_latency(1.0);
    m.record_http_status(200);
    m.record_stage("decode:fp32", 1.0);
    let rendered = m.render_prometheus();
    let doc = read("docs/http_serving.md");
    let mut families = 0;
    for line in rendered.lines() {
        let Some(rest) = line.strip_prefix("# TYPE ") else { continue };
        let name = rest.split_whitespace().next().unwrap();
        assert!(
            doc.contains(name),
            "docs/http_serving.md metrics catalog is missing `{name}`"
        );
        families += 1;
    }
    assert!(families >= 10, "expected ≥10 metric families, saw {families}");
}

#[test]
fn architecture_doc_names_the_http_modules() {
    let arch = read("docs/ARCHITECTURE.md");
    for needle in ["coordinator/http.rs", "coordinator/loadgen.rs"] {
        assert!(
            arch.contains(needle),
            "docs/ARCHITECTURE.md must name {needle}"
        );
    }
}

#[test]
fn architecture_doc_names_every_top_level_module() {
    // The module map can't silently rot: every `pub mod` in lib.rs must
    // appear somewhere in docs/ARCHITECTURE.md.
    let lib = read("rust/src/lib.rs");
    let arch = read("docs/ARCHITECTURE.md");
    let mut found = 0;
    for line in lib.lines() {
        let t = line.trim();
        if let Some(m) = t.strip_prefix("pub mod ") {
            let name = m.trim_end_matches(';');
            assert!(
                arch.contains(name),
                "docs/ARCHITECTURE.md does not mention module `{name}`"
            );
            found += 1;
        }
    }
    assert!(found >= 10, "expected ≥10 top-level modules, found {found}");
}
