//! Integration: PJRT runtime loads + executes the AOT artifacts and the
//! results agree with the pure-Rust engine / expectations.
//! Requires `make artifacts` (skipped gracefully when absent).

use arcquant::model::{Engine, EngineMode, ModelConfig, Weights};
use arcquant::runtime::{Manifest, ModelBundle, Runtime};

fn artifacts_root() -> Option<String> {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{root}/manifest.json")).exists() {
        Some(root.to_string())
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn fp32_artifact_matches_rust_engine() {
    let Some(root) = artifacts_root() else { return };
    let rt = Runtime::new(&root).unwrap();
    let m = Manifest::load(rt.root()).unwrap();
    let exe = rt.load(&m.model_hlo("llama8b-sim", "fp32").unwrap()).unwrap();

    let cfg = ModelConfig::load(&format!("{root}/llama8b-sim.config.json")).unwrap();
    let w = Weights::load(&format!("{root}/llama8b-sim.weights.bin"), &cfg).unwrap();
    let engine = Engine::new(cfg.clone(), w, EngineMode::Fp32, None).unwrap();

    // one batch of the artifact's fixed shape
    let toks: Vec<u16> = (0..(m.batch * m.seq) as u32).map(|i| ((i * 37 + 5) % 256) as u16).collect();
    let toks_i32: Vec<i32> = toks.iter().map(|&t| t as i32).collect();
    let bundle = ModelBundle::load(rt.root(), "llama8b-sim").unwrap();
    let (logits, dims) = rt
        .run_tokens(&exe, &toks_i32, m.batch, m.seq, bundle.weight_literals().unwrap())
        .unwrap();
    assert_eq!(dims, vec![m.batch, m.seq, m.vocab]);

    // engine computes each sequence independently
    for b in 0..m.batch {
        let seq = &toks[b * m.seq..(b + 1) * m.seq];
        let rust_logits = engine.forward(seq, None, None);
        for t in (0..m.seq).step_by(17) {
            for v in (0..m.vocab).step_by(31) {
                let jax = logits[(b * m.seq + t) * m.vocab + v];
                let rust = rust_logits.at(t, v);
                assert!(
                    (jax - rust).abs() < 2e-2 * (1.0 + rust.abs()),
                    "b{b} t{t} v{v}: jax {jax} vs rust {rust}"
                );
            }
        }
    }
}

#[test]
fn arcquant_artifact_executes_and_is_close_to_fp32() {
    let Some(root) = artifacts_root() else { return };
    let rt = Runtime::new(&root).unwrap();
    let m = Manifest::load(rt.root()).unwrap();
    let fp = rt.load(&m.model_hlo("llama8b-sim", "fp32").unwrap()).unwrap();
    let arc = rt.load(&m.model_hlo("llama8b-sim", "arcquant").unwrap()).unwrap();
    let toks: Vec<i32> = (0..(m.batch * m.seq) as i32).map(|i| (i * 91 + 3) % 256).collect();
    let bundle = ModelBundle::load(rt.root(), "llama8b-sim").unwrap();
    let (lf, _) = rt
        .run_tokens(&fp, &toks, m.batch, m.seq, bundle.weight_literals().unwrap())
        .unwrap();
    let mut extra = bundle.weight_literals().unwrap();
    extra.extend(bundle.plan_literals(false).unwrap());
    let (la, _) = rt.run_tokens(&arc, &toks, m.batch, m.seq, extra).unwrap();
    assert_eq!(lf.len(), la.len());
    assert!(la.iter().all(|v| v.is_finite()));
    // W4A4 with residual compensation: top-1 should mostly agree
    let vocab = m.vocab;
    let rows = lf.len() / vocab;
    let mut agree = 0;
    for r in 0..rows {
        let am = |x: &[f32]| {
            x[r * vocab..(r + 1) * vocab]
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0
        };
        if am(&lf) == am(&la) {
            agree += 1;
        }
    }
    assert!(agree * 10 >= rows * 6, "agreement {agree}/{rows}");
}

#[test]
fn gemm_kernel_artifact_matches_rust_gemm() {
    let Some(root) = artifacts_root() else { return };
    let rt = Runtime::new(&root).unwrap();
    let m = Manifest::load(rt.root()).unwrap();
    let path = m.raw.get("kernels").unwrap().get("gemm_aug").unwrap()
        .get("128").unwrap().as_str().unwrap().to_string();
    let exe = rt.load(&path).unwrap();
    // shapes from aot.py: x [64, 1152], w [128, 1152]
    let (n, kk, mm) = (64usize, 256 * 4 + 128, 128usize);
    let mut rng = arcquant::util::Prng::new(7);
    let x: Vec<f32> = (0..n * kk).map(|_| rng.normal()).collect();
    let w: Vec<f32> = (0..mm * kk).map(|_| rng.normal()).collect();
    let (y, dims) = rt
        .run_f32(&exe, &[(&x, &[n, kk]), (&w, &[mm, kk])])
        .unwrap();
    assert_eq!(dims, vec![n, mm]);
    let xm = arcquant::tensor::Mat::from_vec(n, kk, x);
    let wm = arcquant::tensor::Mat::from_vec(mm, kk, w);
    let want = arcquant::tensor::matmul_nt(&xm, &wm);
    for (a, b) in y.iter().zip(&want.data) {
        assert!((a - b).abs() < 1e-2 * (1.0 + b.abs()), "{a} vs {b}");
    }
}
