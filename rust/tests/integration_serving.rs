//! Integration: the full serving stack over the PJRT artifacts (skips
//! gracefully when artifacts are absent), plus the Rust-native serving
//! path — router → batcher → engine executor — which needs no artifacts
//! and is how the packed-execution datapath serves traffic.

use arcquant::coordinator::{
    serve_workload, serve_workload_native, BatcherConfig, NativeServeConfig,
    RouterConfig, ServeConfig, Variant,
};

fn artifacts_root() -> Option<String> {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{root}/manifest.json")).exists() {
        Some(root.to_string())
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn stream() -> Vec<u16> {
    // Use the model's actual eval corpus: a synthetic modular stream is
    // out-of-distribution for the trained LM and its PPL is unbounded.
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let bytes = std::fs::read(format!("{root}/corpus_wiki.bin")).expect("corpus");
    bytes
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .take(50_000)
        .collect()
}

#[test]
fn serving_completes_all_requests_and_reports_sane_stats() {
    let Some(root) = artifacts_root() else { return };
    let cfg = ServeConfig {
        artifacts: root,
        model: "llama8b-sim".into(),
        workload: vec![(Variant::Fp32, 6), (Variant::ArcQuant, 3)],
        req_len: 48,
        batcher: BatcherConfig::default(),
        router: RouterConfig::default(),
    };
    let r = serve_workload(&cfg, &stream()).unwrap();
    assert_eq!(r.completed, 9);
    assert_eq!(r.rejected, 0);
    assert!(r.p50_ms > 0.0 && r.p99_ms >= r.p50_ms);
    let fp = &r.per_variant["fp32"];
    assert_eq!(fp.requests, 6);
    assert!(fp.ppl.is_finite() && fp.ppl > 1.0 && fp.ppl < 200.0);
    let arc = &r.per_variant["arcquant"];
    assert_eq!(arc.requests, 3);
    // W4A4 ARCQuant PPL within 25% of FP32 on this model
    assert!(
        (arc.ppl / fp.ppl - 1.0).abs() < 0.25,
        "arc {} vs fp {}",
        arc.ppl,
        fp.ppl
    );
    // breakdown contains compile + execute stages
    let stages: Vec<&str> = r.stage_breakdown.iter().map(|(s, _, _)| s.as_str()).collect();
    assert!(stages.iter().any(|s| s.starts_with("execute:fp32")));
    assert!(stages.iter().any(|s| s.starts_with("compile:")));
}

#[test]
fn native_serving_runs_packed_and_qdq_without_artifacts() {
    use arcquant::baselines::Method;
    use arcquant::formats::Format;
    use arcquant::model::{Engine, EngineMode, ModelConfig, Weights};
    use std::collections::BTreeMap;

    // synthetic model + calibration: no artifacts required
    let cfg = ModelConfig::tiny_test();
    let weights = Weights::synthetic(&cfg, 3);
    let fp = Engine::new(cfg.clone(), weights.clone(), EngineMode::Fp32, None).unwrap();
    let mut coll = BTreeMap::new();
    let calib_toks: Vec<u16> = (0..64u16).map(|i| (i * 37) % 256).collect();
    fp.forward(&calib_toks, Some(&mut coll), None);

    let method = Method::ArcQuant { fmt: Format::Nvfp4, max_s: Some(64) };
    let qdq = Engine::new(
        cfg.clone(),
        weights.clone(),
        EngineMode::Quantized(method.clone()),
        Some(&coll),
    )
    .unwrap();
    let packed = Engine::new(
        cfg.clone(),
        weights.clone(),
        EngineMode::QuantizedPacked(method),
        Some(&coll),
    )
    .unwrap();
    // packed engine reports real (small) weight bytes
    assert!(packed.weight_bytes() < fp.weight_bytes() / 2);

    let stream: Vec<u16> = (0..4096u32).map(|i| ((i * 37 + 11) % 256) as u16).collect();
    let ncfg = NativeServeConfig {
        workload: vec![
            (Variant::Fp32, 5),
            (Variant::ArcQuant, 4),
            (Variant::ArcPacked, 4),
        ],
        req_len: 24,
        batcher: BatcherConfig::default(),
        router: RouterConfig::default(),
    };
    let engines: Vec<(Variant, &Engine)> = vec![
        (Variant::Fp32, &fp),
        (Variant::ArcQuant, &qdq),
        (Variant::ArcPacked, &packed),
    ];
    let r = serve_workload_native(&ncfg, &stream, &engines).unwrap();
    assert_eq!(r.completed, 13);
    assert_eq!(r.rejected, 0);
    assert_eq!(r.platform, "native-rust");
    for key in ["fp32", "arcquant", "arcquant-packed"] {
        let s = &r.per_variant[key];
        assert!(s.ppl.is_finite() && s.ppl > 1.0, "{key}: ppl {}", s.ppl);
        assert!(s.throughput_tok_s > 0.0);
    }
    // the packed datapath serves the same numbers as the QDQ simulation
    let (a, p) = (
        r.per_variant["arcquant"].ppl,
        r.per_variant["arcquant-packed"].ppl,
    );
    assert!((p / a - 1.0).abs() < 0.02, "packed ppl {p} vs qdq ppl {a}");
    // execute stages recorded per variant
    let stages: Vec<&str> =
        r.stage_breakdown.iter().map(|(s, _, _)| s.as_str()).collect();
    assert!(stages.iter().any(|s| s.starts_with("execute:arcquant-packed")));
}

#[test]
fn native_serving_reports_missing_engine_variants() {
    use arcquant::model::{Engine, EngineMode, ModelConfig, Weights};
    let cfg = ModelConfig::tiny_test();
    let weights = Weights::synthetic(&cfg, 5);
    let fp = Engine::new(cfg, weights, EngineMode::Fp32, None).unwrap();
    let stream: Vec<u16> = (0..2048u32).map(|i| ((i * 91 + 3) % 256) as u16).collect();
    let ncfg = NativeServeConfig {
        workload: vec![(Variant::Fp32, 2), (Variant::Nvfp4Rtn, 2)],
        req_len: 16,
        batcher: BatcherConfig::default(),
        router: RouterConfig::default(),
    };
    let engines: Vec<(Variant, &Engine)> = vec![(Variant::Fp32, &fp)];
    let r = serve_workload_native(&ncfg, &stream, &engines).unwrap();
    // all responses come back; the engine-less variant yields empty
    // logits, so only fp32 contributes stats
    assert_eq!(r.completed, 4);
    assert!(r.per_variant.contains_key("fp32"));
    assert!(!r.per_variant.contains_key("nvfp4rtn"));
}

#[test]
fn serving_fp32_variant_matches_engine_ppl_ballpark() {
    let Some(root) = artifacts_root() else { return };
    let s = stream();
    let cfg = ServeConfig {
        artifacts: root.clone(),
        model: "llama8b-sim".into(),
        workload: vec![(Variant::Fp32, 4)],
        req_len: 64,
        batcher: BatcherConfig::default(),
        router: RouterConfig::default(),
    };
    let r = serve_workload(&cfg, &s).unwrap();
    let served_ppl = r.per_variant["fp32"].ppl;

    // same stream through the native engine
    use arcquant::model::{Engine, EngineMode, ModelConfig, Weights};
    let cfgm = ModelConfig::load(&format!("{root}/llama8b-sim.config.json")).unwrap();
    let w = Weights::load(&format!("{root}/llama8b-sim.weights.bin"), &cfgm).unwrap();
    let e = Engine::new(cfgm, w, EngineMode::Fp32, None).unwrap();
    let native = arcquant::eval::perplexity(&e, &s, 63, 4).ppl;
    assert!(
        (served_ppl / native - 1.0).abs() < 0.35,
        "served {served_ppl} vs native {native}"
    );
}
