//! Integration: the full serving stack over the PJRT artifacts (skips
//! gracefully when artifacts are absent), plus the Rust-native serving
//! path — router → batcher → engine executor — which needs no artifacts
//! and is how the packed-execution datapath serves traffic, plus the
//! generation path (continuous-batching decode over the paged KV-cache).

use arcquant::coordinator::{
    serve_generate_native, serve_workload, serve_workload_native, session_rng,
    BatcherConfig, FinishReason, GenerateServeConfig, NativeServeConfig, RouterConfig,
    ServeConfig, Variant,
};

fn artifacts_root() -> Option<String> {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{root}/manifest.json")).exists() {
        Some(root.to_string())
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn stream() -> Vec<u16> {
    // Use the model's actual eval corpus: a synthetic modular stream is
    // out-of-distribution for the trained LM and its PPL is unbounded.
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let bytes = std::fs::read(format!("{root}/corpus_wiki.bin")).expect("corpus");
    bytes
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .take(50_000)
        .collect()
}

#[test]
fn serving_completes_all_requests_and_reports_sane_stats() {
    let Some(root) = artifacts_root() else { return };
    let cfg = ServeConfig {
        artifacts: root,
        model: "llama8b-sim".into(),
        workload: vec![(Variant::Fp32, 6), (Variant::ArcQuant, 3)],
        req_len: 48,
        batcher: BatcherConfig::default(),
        router: RouterConfig::default(),
    };
    let r = serve_workload(&cfg, &stream()).unwrap();
    assert_eq!(r.completed, 9);
    assert_eq!(r.rejected, 0);
    assert!(r.p50_ms > 0.0 && r.p99_ms >= r.p50_ms);
    let fp = &r.per_variant["fp32"];
    assert_eq!(fp.requests, 6);
    assert!(fp.ppl.is_finite() && fp.ppl > 1.0 && fp.ppl < 200.0);
    let arc = &r.per_variant["arcquant"];
    assert_eq!(arc.requests, 3);
    // W4A4 ARCQuant PPL within 25% of FP32 on this model
    assert!(
        (arc.ppl / fp.ppl - 1.0).abs() < 0.25,
        "arc {} vs fp {}",
        arc.ppl,
        fp.ppl
    );
    // breakdown contains compile + execute stages
    let stages: Vec<&str> = r.stage_breakdown.iter().map(|(s, _, _)| s.as_str()).collect();
    assert!(stages.iter().any(|s| s.starts_with("execute:fp32")));
    assert!(stages.iter().any(|s| s.starts_with("compile:")));
}

#[test]
fn native_serving_runs_packed_and_qdq_without_artifacts() {
    use arcquant::baselines::Method;
    use arcquant::formats::Format;
    use arcquant::model::{Engine, EngineMode, ModelConfig, Weights};
    use std::collections::BTreeMap;

    // synthetic model + calibration: no artifacts required
    let cfg = ModelConfig::tiny_test();
    let weights = Weights::synthetic(&cfg, 3);
    let fp = Engine::new(cfg.clone(), weights.clone(), EngineMode::Fp32, None).unwrap();
    let mut coll = BTreeMap::new();
    let calib_toks: Vec<u16> = (0..64u16).map(|i| (i * 37) % 256).collect();
    fp.forward(&calib_toks, Some(&mut coll), None);

    let method = Method::ArcQuant { fmt: Format::Nvfp4, max_s: Some(64) };
    let qdq = Engine::new(
        cfg.clone(),
        weights.clone(),
        EngineMode::Quantized(method.clone()),
        Some(&coll),
    )
    .unwrap();
    let packed = Engine::new(
        cfg.clone(),
        weights.clone(),
        EngineMode::QuantizedPacked(method),
        Some(&coll),
    )
    .unwrap();
    // packed engine reports real (small) weight bytes
    assert!(packed.weight_bytes() < fp.weight_bytes() / 2);

    let stream: Vec<u16> = (0..4096u32).map(|i| ((i * 37 + 11) % 256) as u16).collect();
    let ncfg = NativeServeConfig {
        workload: vec![
            (Variant::Fp32, 5),
            (Variant::ArcQuant, 4),
            (Variant::ArcPacked, 4),
        ],
        req_len: 24,
        batcher: BatcherConfig::default(),
        router: RouterConfig::default(),
    };
    let engines: Vec<(Variant, &Engine)> = vec![
        (Variant::Fp32, &fp),
        (Variant::ArcQuant, &qdq),
        (Variant::ArcPacked, &packed),
    ];
    let r = serve_workload_native(&ncfg, &stream, &engines).unwrap();
    assert_eq!(r.completed, 13);
    assert_eq!(r.rejected, 0);
    assert_eq!(r.platform, "native-rust");
    for key in ["fp32", "arcquant", "arcquant-packed"] {
        let s = &r.per_variant[key];
        assert!(s.ppl.is_finite() && s.ppl > 1.0, "{key}: ppl {}", s.ppl);
        assert!(s.throughput_tok_s > 0.0);
    }
    // the packed datapath serves the same numbers as the QDQ simulation
    let (a, p) = (
        r.per_variant["arcquant"].ppl,
        r.per_variant["arcquant-packed"].ppl,
    );
    assert!((p / a - 1.0).abs() < 0.02, "packed ppl {p} vs qdq ppl {a}");
    // execute stages recorded per variant
    let stages: Vec<&str> =
        r.stage_breakdown.iter().map(|(s, _, _)| s.as_str()).collect();
    assert!(stages.iter().any(|s| s.starts_with("execute:arcquant-packed")));
}

#[test]
fn native_serving_reports_missing_engine_variants() {
    use arcquant::model::{Engine, EngineMode, ModelConfig, Weights};
    let cfg = ModelConfig::tiny_test();
    let weights = Weights::synthetic(&cfg, 5);
    let fp = Engine::new(cfg, weights, EngineMode::Fp32, None).unwrap();
    let stream: Vec<u16> = (0..2048u32).map(|i| ((i * 91 + 3) % 256) as u16).collect();
    let ncfg = NativeServeConfig {
        workload: vec![(Variant::Fp32, 2), (Variant::Nvfp4Rtn, 2)],
        req_len: 16,
        batcher: BatcherConfig::default(),
        router: RouterConfig::default(),
    };
    let engines: Vec<(Variant, &Engine)> = vec![(Variant::Fp32, &fp)];
    let r = serve_workload_native(&ncfg, &stream, &engines).unwrap();
    // all responses come back; the engine-less variant yields empty
    // logits, so only fp32 contributes stats
    assert_eq!(r.completed, 4);
    assert!(r.per_variant.contains_key("fp32"));
    assert!(!r.per_variant.contains_key("nvfp4rtn"));
}

/// Shared fixture for generation tests: tiny fp32 + QDQ + packed engines
/// over one synthetic calibration.
fn gen_engines() -> Vec<(Variant, arcquant::model::Engine)> {
    use arcquant::baselines::Method;
    use arcquant::formats::Format;
    use arcquant::model::{Engine, EngineMode, ModelConfig, Weights};
    use std::collections::BTreeMap;

    let cfg = ModelConfig::tiny_test();
    let weights = Weights::synthetic(&cfg, 3);
    let fp = Engine::new(cfg.clone(), weights.clone(), EngineMode::Fp32, None).unwrap();
    let mut coll = BTreeMap::new();
    let calib_toks: Vec<u16> = (0..64u16).map(|i| (i * 37) % 256).collect();
    fp.forward(&calib_toks, Some(&mut coll), None);
    let method = Method::ArcQuant { fmt: Format::Nvfp4, max_s: Some(64) };
    let qdq = Engine::new(
        cfg.clone(),
        weights.clone(),
        EngineMode::Quantized(method.clone()),
        Some(&coll),
    )
    .unwrap();
    let packed = Engine::new(
        cfg.clone(),
        weights,
        EngineMode::QuantizedPacked(method),
        Some(&coll),
    )
    .unwrap();
    vec![
        (Variant::Fp32, fp),
        (Variant::ArcQuant, qdq),
        (Variant::ArcPacked, packed),
    ]
}

fn synth_stream() -> Vec<u16> {
    (0..4096u32).map(|i| ((i * 37 + 11) % 256) as u16).collect()
}

#[test]
fn generation_tokens_match_reference_decode_loop_bit_exact() {
    use arcquant::model::{KvCache, Sampler};

    // Mixed prefill+decode generation traffic across all three variants,
    // through the continuous-batching executor...
    let engines = gen_engines();
    let refs: Vec<(Variant, &arcquant::model::Engine)> =
        engines.iter().map(|(v, e)| (*v, e)).collect();
    let stream = synth_stream();
    let cfg = GenerateServeConfig {
        workload: vec![
            (Variant::Fp32, 3),
            (Variant::ArcQuant, 3),
            (Variant::ArcPacked, 4),
        ],
        prompt_len: 24,
        max_new_tokens: 8,
        max_decode_batch: 4,
        kv_pages: 256,
        sampler: Sampler::Greedy,
        seed: 7,
        ..Default::default()
    };
    let r = serve_generate_native(&cfg, &stream, &refs).unwrap();
    assert_eq!(r.completed, 10);
    assert_eq!(r.rejected, 0);
    assert_eq!(r.responses.len(), 10);
    assert_eq!(r.platform, "native-rust");

    // ...must produce, per request, exactly the tokens of an independent
    // per-sequence prefill + decode_step loop (the batched decode is
    // bit-identical per row, so greedy argmax can never diverge).
    for resp in &r.responses {
        assert_eq!(resp.finish, FinishReason::Length, "id {}", resp.id);
        assert_eq!(resp.tokens.len(), cfg.max_new_tokens);
        let engine = refs.iter().find(|(v, _)| *v == resp.variant).map(|(_, e)| *e).unwrap();
        // same prompt reconstruction as the submission side
        let idx = (resp.id - 1) as usize;
        let per_variant_r = cfg
            .workload
            .iter()
            .scan(0usize, |acc, &(v, n)| {
                let lo = *acc;
                *acc += n;
                Some((v, lo))
            })
            .find(|&(v, _)| v == resp.variant)
            .map(|(_, lo)| idx - lo)
            .unwrap();
        let start = (per_variant_r * (cfg.prompt_len + 5))
            % (stream.len() - cfg.prompt_len - 1);
        let prompt = &stream[start..start + cfg.prompt_len];

        let mut rng = session_rng(cfg.seed, resp.id);
        let mut cache = KvCache::new(&engine.cfg, cfg.prompt_len + cfg.max_new_tokens);
        let mut tok = cfg
            .sampler
            .sample(&engine.prefill(prompt, &mut cache).unwrap(), &mut rng);
        let mut want = vec![tok];
        for _ in 1..cfg.max_new_tokens {
            tok = cfg
                .sampler
                .sample(&engine.decode_step(tok, &mut cache).unwrap(), &mut rng);
            want.push(tok);
        }
        assert_eq!(
            resp.tokens, want,
            "id {} ({:?}): served generation diverged from reference loop",
            resp.id, resp.variant
        );
    }

    // decode stats: every variant decoded in batches, throughput recorded,
    // and the stage breakdown shows the mixed prefill+decode pipeline
    for key in ["fp32", "arcquant", "arcquant-packed"] {
        let s = &r.per_variant[key];
        assert!(s.requests >= 3, "{key}");
        assert!(s.decode_tok_s > 0.0, "{key}");
        assert!(s.decode_ticks >= 7, "{key}: {} ticks", s.decode_ticks);
        assert!(s.mean_decode_batch > 1.0, "{key}: batching never happened");
        assert_eq!(s.oom_truncated, 0, "{key}");
    }
    let stages: Vec<&str> =
        r.stage_breakdown.iter().map(|(s, _, _)| s.as_str()).collect();
    assert!(stages.iter().any(|s| s.starts_with("prefill:arcquant-packed")));
    assert!(stages.iter().any(|s| s.starts_with("decode:arcquant-packed")));
    assert!(stages.iter().any(|s| s.starts_with("decode:fp32")));

    // page accounting surfaced in the report
    assert!(r.kv_pages_peak > 0 && r.kv_pages_peak <= r.kv_pages_total);
    assert!(r.kv_bytes_peak > 0);
    assert_eq!(
        r.kv_bytes_peak,
        r.kv_pages_peak as u64 * r.kv_bytes_per_page
    );
}

#[test]
fn generation_with_nvfp4_kv_replays_bit_exact_and_admits_more() {
    use arcquant::formats::KvFormat;
    use arcquant::model::{KvCache, Sampler};

    // Same closed-loop workload under f32 and NVFP4 K/V pages, on a
    // deliberately scarce page pool: each request's worst case is
    // 24 + 8 = 32 tokens — 2 f32 pages (16 tokens each) but a single
    // NVFP4 page (107 tokens at d=128, l=2). With 3 pages total, f32 can
    // only run one sequence at a time while NVFP4 runs three — the
    // capacity lever the quantized KV cache exists for.
    let engines = gen_engines();
    let refs: Vec<(Variant, &arcquant::model::Engine)> =
        engines.iter().map(|(v, e)| (*v, e)).collect();
    let stream = synth_stream();
    let base = GenerateServeConfig {
        workload: vec![(Variant::ArcPacked, 6)],
        prompt_len: 24,
        max_new_tokens: 8,
        max_decode_batch: 8,
        kv_pages: 3,
        sampler: Sampler::Greedy,
        seed: 11,
        ..Default::default()
    };
    let run = |kv: KvFormat| {
        let cfg = GenerateServeConfig { kv_format: kv, ..base.clone() };
        serve_generate_native(&cfg, &stream, &refs).unwrap()
    };
    let fp = run(KvFormat::Fp32);
    let nv = run(KvFormat::Nvfp4);
    for r in [&fp, &nv] {
        assert_eq!(r.completed, 6);
        assert_eq!(r.rejected, 0);
        assert!(r
            .responses
            .iter()
            .all(|resp| resp.finish == FinishReason::Length
                && resp.tokens.len() == base.max_new_tokens));
    }
    assert_eq!(fp.kv_format, "fp32");
    assert_eq!(nv.kv_format, "nvfp4");
    assert_eq!(fp.kv_page_tokens, 16);
    assert_eq!(nv.kv_page_tokens, 107);
    // f32 pages force one-at-a-time admission; NVFP4 pages batch all 6
    // (the decode batch fills up as soon as pages stop being the limit)
    let (b_fp, b_nv) = (
        fp.per_variant["arcquant-packed"].mean_decode_batch,
        nv.per_variant["arcquant-packed"].mean_decode_batch,
    );
    assert!((b_fp - 1.0).abs() < 1e-9, "f32 should serialize: {b_fp}");
    assert!(b_nv > 2.5, "nvfp4 should batch: {b_nv}");
    // quantized pages also report their real (smaller) byte footprint
    assert!(nv.kv_bytes_per_page <= fp.kv_bytes_per_page);

    // Bit-exact replay: every served NVFP4-KV generation equals an
    // independent prefill + decode_step loop over an NVFP4 cache.
    let engine = refs
        .iter()
        .find(|(v, _)| *v == Variant::ArcPacked)
        .map(|(_, e)| *e)
        .unwrap();
    for resp in &nv.responses {
        let idx = (resp.id - 1) as usize;
        let start =
            (idx * (base.prompt_len + 5)) % (stream.len() - base.prompt_len - 1);
        let prompt = &stream[start..start + base.prompt_len];
        let mut rng = session_rng(base.seed, resp.id);
        let mut cache = KvCache::with_format(
            &engine.cfg,
            base.prompt_len + base.max_new_tokens,
            KvFormat::Nvfp4,
        );
        let mut tok = base
            .sampler
            .sample(&engine.prefill(prompt, &mut cache).unwrap(), &mut rng);
        let mut want = vec![tok];
        for _ in 1..base.max_new_tokens {
            tok = base
                .sampler
                .sample(&engine.decode_step(tok, &mut cache).unwrap(), &mut rng);
            want.push(tok);
        }
        assert_eq!(
            resp.tokens, want,
            "id {}: served nvfp4-KV generation diverged from reference",
            resp.id
        );
    }
}

#[test]
fn generation_rejects_prompts_exceeding_the_page_budget() {
    use arcquant::model::Sampler;
    let engines = gen_engines();
    let refs: Vec<(Variant, &arcquant::model::Engine)> =
        engines.iter().map(|(v, e)| (*v, e)).collect();
    let stream = synth_stream();
    // prompt needs 2 pages (24 tokens, 16-token pages); pool has 1 → no
    // request can ever run
    let cfg = GenerateServeConfig {
        workload: vec![(Variant::ArcPacked, 3)],
        prompt_len: 24,
        max_new_tokens: 4,
        max_decode_batch: 4,
        kv_pages: 1,
        sampler: Sampler::Greedy,
        seed: 0,
        ..Default::default()
    };
    let r = serve_generate_native(&cfg, &stream, &refs).unwrap();
    assert_eq!(r.completed, 0);
    assert_eq!(r.rejected, 3);
    assert!(r
        .responses
        .iter()
        .all(|resp| resp.finish == FinishReason::Rejected && resp.tokens.is_empty()));
}

#[test]
fn generation_backpressure_serializes_when_pages_are_scarce() {
    use arcquant::model::Sampler;
    let engines = gen_engines();
    let refs: Vec<(Variant, &arcquant::model::Engine)> =
        engines.iter().map(|(v, e)| (*v, e)).collect();
    let stream = synth_stream();
    // each sequence peaks at 16 + 7 = 23 tokens → 2 pages; a 2-page pool
    // forces one-at-a-time admission, but everything still completes
    let cfg = GenerateServeConfig {
        workload: vec![(Variant::Fp32, 3)],
        prompt_len: 16,
        max_new_tokens: 8,
        max_decode_batch: 4,
        kv_pages: 2,
        sampler: Sampler::Greedy,
        seed: 0,
        ..Default::default()
    };
    let r = serve_generate_native(&cfg, &stream, &refs).unwrap();
    assert_eq!(r.completed, 3);
    assert_eq!(r.rejected, 0);
    assert!(r
        .responses
        .iter()
        .all(|resp| resp.finish == FinishReason::Length
            && resp.tokens.len() == cfg.max_new_tokens));
    // pages were the bottleneck: the pool never exceeded its 2 pages
    assert!(r.kv_pages_peak <= 2);
    // decode could never batch: one running sequence at a time
    assert!((r.per_variant["fp32"].mean_decode_batch - 1.0).abs() < 1e-9);
}

#[test]
fn generation_truncates_on_mid_decode_page_exhaustion() {
    use arcquant::model::Sampler;
    let engines = gen_engines();
    let refs: Vec<(Variant, &arcquant::model::Engine)> =
        engines.iter().map(|(v, e)| (*v, e)).collect();
    let stream = synth_stream();
    // Each sequence: 16-token prompt (1 page), worst case 36 tokens
    // (3 pages). A 4-page pool passes the admission headroom check for
    // both sequences (free 4 ≥ 3, then free 3 ≥ 3) — a deliberate
    // over-commit: combined worst case is 6 pages. Both grow to 2 pages;
    // at the 33-token boundary the pool is exhausted, the first sequence
    // retires OutOfPages (releasing its pages) and the second takes the
    // freed pages and completes its full budget.
    let cfg = GenerateServeConfig {
        workload: vec![(Variant::ArcQuant, 2)],
        prompt_len: 16,
        max_new_tokens: 20,
        max_decode_batch: 4,
        kv_pages: 4,
        sampler: Sampler::Greedy,
        seed: 0,
        ..Default::default()
    };
    let r = serve_generate_native(&cfg, &stream, &refs).unwrap();
    assert_eq!(r.completed, 2);
    assert_eq!(r.rejected, 0);
    let finished: Vec<FinishReason> =
        r.responses.iter().map(|resp| resp.finish).collect();
    assert!(finished.contains(&FinishReason::Length), "{finished:?}");
    assert!(finished.contains(&FinishReason::OutOfPages), "{finished:?}");
    let oom = r
        .responses
        .iter()
        .find(|resp| resp.finish == FinishReason::OutOfPages)
        .unwrap();
    assert!(
        !oom.tokens.is_empty() && oom.tokens.len() < cfg.max_new_tokens,
        "truncated mid-generation: {} tokens",
        oom.tokens.len()
    );
    let full = r
        .responses
        .iter()
        .find(|resp| resp.finish == FinishReason::Length)
        .unwrap();
    assert_eq!(full.tokens.len(), cfg.max_new_tokens);
    assert_eq!(r.per_variant["arcquant"].oom_truncated, 1);
    assert!(r.kv_pages_peak <= 4);
}

#[test]
fn serving_fp32_variant_matches_engine_ppl_ballpark() {
    let Some(root) = artifacts_root() else { return };
    let s = stream();
    let cfg = ServeConfig {
        artifacts: root.clone(),
        model: "llama8b-sim".into(),
        workload: vec![(Variant::Fp32, 4)],
        req_len: 64,
        batcher: BatcherConfig::default(),
        router: RouterConfig::default(),
    };
    let r = serve_workload(&cfg, &s).unwrap();
    let served_ppl = r.per_variant["fp32"].ppl;

    // same stream through the native engine
    use arcquant::model::{Engine, EngineMode, ModelConfig, Weights};
    let cfgm = ModelConfig::load(&format!("{root}/llama8b-sim.config.json")).unwrap();
    let w = Weights::load(&format!("{root}/llama8b-sim.weights.bin"), &cfgm).unwrap();
    let e = Engine::new(cfgm, w, EngineMode::Fp32, None).unwrap();
    let native = arcquant::eval::perplexity(&e, &s, 63, 4).ppl;
    assert!(
        (served_ppl / native - 1.0).abs() < 0.35,
        "served {served_ppl} vs native {native}"
    );
}
