//! Integration: the full serving stack over the PJRT artifacts.
//! Skips gracefully when artifacts are absent.

use arcquant::coordinator::{
    serve_workload, BatcherConfig, RouterConfig, ServeConfig, Variant,
};

fn artifacts_root() -> Option<String> {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(&format!("{root}/manifest.json")).exists() {
        Some(root.to_string())
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

fn stream() -> Vec<u16> {
    // Use the model's actual eval corpus: a synthetic modular stream is
    // out-of-distribution for the trained LM and its PPL is unbounded.
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let bytes = std::fs::read(format!("{root}/corpus_wiki.bin")).expect("corpus");
    bytes
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .take(50_000)
        .collect()
}

#[test]
fn serving_completes_all_requests_and_reports_sane_stats() {
    let Some(root) = artifacts_root() else { return };
    let cfg = ServeConfig {
        artifacts: root,
        model: "llama8b-sim".into(),
        workload: vec![(Variant::Fp32, 6), (Variant::ArcQuant, 3)],
        req_len: 48,
        batcher: BatcherConfig::default(),
        router: RouterConfig::default(),
    };
    let r = serve_workload(&cfg, &stream()).unwrap();
    assert_eq!(r.completed, 9);
    assert_eq!(r.rejected, 0);
    assert!(r.p50_ms > 0.0 && r.p99_ms >= r.p50_ms);
    let fp = &r.per_variant["fp32"];
    assert_eq!(fp.requests, 6);
    assert!(fp.ppl.is_finite() && fp.ppl > 1.0 && fp.ppl < 200.0);
    let arc = &r.per_variant["arcquant"];
    assert_eq!(arc.requests, 3);
    // W4A4 ARCQuant PPL within 25% of FP32 on this model
    assert!(
        (arc.ppl / fp.ppl - 1.0).abs() < 0.25,
        "arc {} vs fp {}",
        arc.ppl,
        fp.ppl
    );
    // breakdown contains compile + execute stages
    let stages: Vec<&str> = r.stage_breakdown.iter().map(|(s, _, _)| s.as_str()).collect();
    assert!(stages.iter().any(|s| s.starts_with("execute:fp32")));
    assert!(stages.iter().any(|s| s.starts_with("compile:")));
}

#[test]
fn serving_fp32_variant_matches_engine_ppl_ballpark() {
    let Some(root) = artifacts_root() else { return };
    let s = stream();
    let cfg = ServeConfig {
        artifacts: root.clone(),
        model: "llama8b-sim".into(),
        workload: vec![(Variant::Fp32, 4)],
        req_len: 64,
        batcher: BatcherConfig::default(),
        router: RouterConfig::default(),
    };
    let r = serve_workload(&cfg, &s).unwrap();
    let served_ppl = r.per_variant["fp32"].ppl;

    // same stream through the native engine
    use arcquant::model::{Engine, EngineMode, ModelConfig, Weights};
    let cfgm = ModelConfig::load(&format!("{root}/llama8b-sim.config.json")).unwrap();
    let w = Weights::load(&format!("{root}/llama8b-sim.weights.bin"), &cfgm).unwrap();
    let e = Engine::new(cfgm, w, EngineMode::Fp32, None).unwrap();
    let native = arcquant::eval::perplexity(&e, &s, 63, 4).ppl;
    assert!(
        (served_ppl / native - 1.0).abs() < 0.35,
        "served {served_ppl} vs native {native}"
    );
}
