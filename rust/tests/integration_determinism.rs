//! Determinism pins for the persistent worker pool: single-threaded
//! (`ARCQUANT_THREADS=1`-equivalent) and multi-threaded execution must
//! produce bit-identical output for the packed and QDQ GEMMs, the
//! activation quantizers, and the full packed ARCQuant forward.
//!
//! The pool decomposes work into chunks whose boundaries never affect
//! per-element arithmetic, so this is an invariant of the design — these
//! tests pin it. They live in their own integration binary because the
//! thread override is process-global: unit tests of the library run in
//! one process and must not race against it.

use arcquant::formats::{Format, RowQuantizer};
use arcquant::quant::{LayerPlan, PackedArcLinear};
use arcquant::tensor::{matmul_nt, matmul_nt_packed, matmul_nt_packed_ref, Mat};
use arcquant::util::pool;
use arcquant::util::prop::gens::outlier_mat;
use arcquant::util::Prng;

/// Everything the serving hot path parallelises, evaluated once: the
/// returned buffers are compared bitwise across thread counts.
fn run_all(x: &Mat, w: &Mat) -> Vec<Vec<f32>> {
    let mut outs: Vec<Vec<f32>> = Vec::new();
    for fmt in [Format::Nvfp4, Format::Mxfp4, Format::Int4 { group: 16 }] {
        let q = RowQuantizer::new(fmt);
        let (qa, qb) = (q.quantize(x), q.quantize(w));
        // packed GEMM, tiled shape
        outs.push(matmul_nt_packed(&qa, &qb).data);
        // packed GEMM, n = 1 decode shape (column-parallel row kernel)
        let row = Mat::from_vec(1, x.cols, x.row(0).to_vec());
        outs.push(matmul_nt_packed(&q.quantize(&row), &qb).data);
        // pre-v2 reference kernel (also pool-parallelised)
        outs.push(matmul_nt_packed_ref(&qa, &qb).data);
        // QDQ GEMM over dequantized operands
        outs.push(matmul_nt(&qa.dequantize(), &qb.dequantize()).data);
        // row-wise QDQ quantizer (the batched-decode activation path)
        outs.push(q.qdq_mat_rowwise(x).data);
    }
    // full packed ARCQuant forward: reorder + two quantization stages +
    // augmented GEMM, every stage pool-parallelised
    let plan = LayerPlan::from_calibration(&x.col_absmax(), Format::Nvfp4);
    let lin = PackedArcLinear::prepare(w, plan).unwrap();
    outs.push(lin.forward(x).data);
    outs.push(lin.forward_rowwise(x).data);
    outs
}

#[test]
fn single_vs_multi_thread_runs_are_bit_identical() {
    let mut rng = Prng::new(400);
    let x = outlier_mat(&mut rng, 6, 128);
    let mut w = Mat::zeros(9, 128);
    w.fill_random_normal(&mut rng, 0.5);

    pool::set_thread_override(Some(1));
    assert_eq!(pool::num_threads(), 1);
    let single = run_all(&x, &w);
    pool::set_thread_override(Some(8));
    assert_eq!(pool::num_threads(), 8);
    let multi = run_all(&x, &w);
    pool::set_thread_override(None);
    let default = run_all(&x, &w);

    assert_eq!(single.len(), multi.len());
    for (i, (a, b)) in single.iter().zip(&multi).enumerate() {
        assert_eq!(a, b, "output {i} differs between 1 and 8 threads");
    }
    for (i, (a, b)) in single.iter().zip(&default).enumerate() {
        assert_eq!(a, b, "output {i} differs between 1 and default threads");
    }
}
