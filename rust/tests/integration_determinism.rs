//! Determinism pins for the persistent worker pool: single-threaded
//! (`ARCQUANT_THREADS=1`-equivalent) and multi-threaded execution must
//! produce bit-identical output for the packed and QDQ GEMMs, the
//! activation quantizers, and the full packed ARCQuant forward.
//!
//! The pool decomposes work into chunks whose boundaries never affect
//! per-element arithmetic, so this is an invariant of the design — these
//! tests pin it. They live in their own integration binary because the
//! thread override is process-global: unit tests of the library run in
//! one process and must not race against it.
//!
//! The same reasoning covers the SIMD path override
//! ([`arcquant::tensor::simd::set_path_override`]): the scalar and AVX2
//! kernel arms are bit-identical by construction, and the tests here pin
//! scalar-vs-SIMD equality over the full packed forward (including the
//! augmented S ∈ {0, 128, 256} shapes) with the overrides serialized by
//! a local mutex so the two global knobs cannot race each other.

use arcquant::formats::{Format, RowQuantizer};
use arcquant::quant::{LayerPlan, PackedArcLinear, Permutation};
use arcquant::tensor::simd::{self, SimdPath};
use arcquant::tensor::{matmul_nt, matmul_nt_packed, matmul_nt_packed_ref, Mat};
use arcquant::util::pool;
use arcquant::util::prop::gens::outlier_mat;
use arcquant::util::Prng;
use std::sync::Mutex;

/// Serializes every test that mutates a process-global override (thread
/// count or SIMD path) so they cannot interleave.
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

/// Everything the serving hot path parallelises, evaluated once: the
/// returned buffers are compared bitwise across thread counts.
fn run_all(x: &Mat, w: &Mat) -> Vec<Vec<f32>> {
    let mut outs: Vec<Vec<f32>> = Vec::new();
    for fmt in [Format::Nvfp4, Format::Mxfp4, Format::Int4 { group: 16 }] {
        let q = RowQuantizer::new(fmt);
        let (qa, qb) = (q.quantize(x), q.quantize(w));
        // packed GEMM, tiled shape
        outs.push(matmul_nt_packed(&qa, &qb).data);
        // packed GEMM, n = 1 decode shape (column-parallel row kernel)
        let row = Mat::from_vec(1, x.cols, x.row(0).to_vec());
        outs.push(matmul_nt_packed(&q.quantize(&row), &qb).data);
        // pre-v2 reference kernel (also pool-parallelised)
        outs.push(matmul_nt_packed_ref(&qa, &qb).data);
        // QDQ GEMM over dequantized operands
        outs.push(matmul_nt(&qa.dequantize(), &qb.dequantize()).data);
        // row-wise QDQ quantizer (the batched-decode activation path)
        outs.push(q.qdq_mat_rowwise(x).data);
    }
    // full packed ARCQuant forward: reorder + two quantization stages +
    // augmented GEMM, every stage pool-parallelised
    let plan = LayerPlan::from_calibration(&x.col_absmax(), Format::Nvfp4);
    let lin = PackedArcLinear::prepare(w, plan).unwrap();
    outs.push(lin.forward(x).data);
    outs.push(lin.forward_rowwise(x).data);
    outs
}

#[test]
fn single_vs_multi_thread_runs_are_bit_identical() {
    let _guard = OVERRIDE_LOCK.lock().unwrap();
    let mut rng = Prng::new(400);
    let x = outlier_mat(&mut rng, 6, 128);
    let mut w = Mat::zeros(9, 128);
    w.fill_random_normal(&mut rng, 0.5);

    pool::set_thread_override(Some(1));
    assert_eq!(pool::num_threads(), 1);
    let single = run_all(&x, &w);
    pool::set_thread_override(Some(8));
    assert_eq!(pool::num_threads(), 8);
    let multi = run_all(&x, &w);
    pool::set_thread_override(None);
    let default = run_all(&x, &w);

    assert_eq!(single.len(), multi.len());
    for (i, (a, b)) in single.iter().zip(&multi).enumerate() {
        assert_eq!(a, b, "output {i} differs between 1 and 8 threads");
    }
    for (i, (a, b)) in single.iter().zip(&default).enumerate() {
        assert_eq!(a, b, "output {i} differs between 1 and default threads");
    }
}

/// Bitwise f32 comparison: `Vec<f32> ==` would conflate `0.0` and
/// `-0.0`; the SIMD pins must be exact down to the sign of zero.
fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{what}: element {i} differs between scalar and SIMD ({x} vs {y})"
        );
    }
}

#[test]
fn scalar_vs_simd_runs_are_bit_identical() {
    let _guard = OVERRIDE_LOCK.lock().unwrap();
    // On hosts without AVX2 the Avx2 override degrades to scalar and the
    // comparison is trivially true — the CI avx2 matrix leg runs on
    // hardware where both arms are real.
    let mut rng = Prng::new(401);
    let x = outlier_mat(&mut rng, 6, 128);
    let mut w = Mat::zeros(9, 128);
    w.fill_random_normal(&mut rng, 0.5);

    simd::set_path_override(Some(SimdPath::Scalar));
    let scalar = run_all(&x, &w);
    simd::set_path_override(Some(SimdPath::Avx2));
    let vector = run_all(&x, &w);
    simd::set_path_override(None);

    assert_eq!(scalar.len(), vector.len());
    for (i, (a, b)) in scalar.iter().zip(&vector).enumerate() {
        assert_bits_eq(a, b, &format!("output {i}"));
    }
}

#[test]
fn scalar_vs_simd_packed_forward_augmented_shapes() {
    let _guard = OVERRIDE_LOCK.lock().unwrap();
    // The paper's serving shape: K = 1024 with S ∈ {0, 128, 256}
    // augmented residual channels, through the full PackedArcLinear
    // forward (reorder → augment → two quantizations → packed GEMM) and
    // the decode-on-access KV dequant — every stage that dispatches on
    // the SIMD path. Scalar and SIMD arms must agree bit-for-bit.
    let (n, k, m) = (5usize, 1024usize, 24usize);
    let mut rng = Prng::new(402);
    let x = outlier_mat(&mut rng, n, k);
    let mut w = Mat::zeros(m, k);
    w.fill_random_normal(&mut rng, 0.4);

    for s in [0usize, 128, 256] {
        for fmt in [Format::Nvfp4, Format::Mxfp4] {
            let plan = LayerPlan { perm: Permutation::identity(k), s, fmt };
            let lin = PackedArcLinear::prepare(&w, plan).unwrap();

            simd::set_path_override(Some(SimdPath::Scalar));
            let y_scalar = lin.forward(&x);
            let row = Mat::from_vec(1, k, x.row(0).to_vec());
            let y1_scalar = lin.forward(&row); // n = 1 row-kernel route
            simd::set_path_override(Some(SimdPath::Avx2));
            let y_vector = lin.forward(&x);
            let y1_vector = lin.forward(&row);
            simd::set_path_override(None);

            assert_bits_eq(&y_scalar.data, &y_vector.data, &format!("{fmt:?} s={s} batch"));
            assert_bits_eq(&y1_scalar.data, &y1_vector.data, &format!("{fmt:?} s={s} n=1"));
        }
    }

    // KV read: dequant_into of a quantized [T, d] matrix (ragged tail
    // included via d = 120 on NVFP4's g = 16 and MXFP4's g = 32).
    for (fmt, d) in [(Format::Nvfp4, 120usize), (Format::Mxfp4, 104)] {
        let mut kmat = Mat::zeros(33, d);
        kmat.fill_random_normal(&mut rng, 0.8);
        let qk = RowQuantizer::new(fmt).quantize(&kmat);
        let mut out_scalar = vec![0f32; 33 * d];
        let mut out_vector = vec![0f32; 33 * d];
        simd::set_path_override(Some(SimdPath::Scalar));
        qk.dequant_into(&mut out_scalar);
        simd::set_path_override(Some(SimdPath::Avx2));
        qk.dequant_into(&mut out_vector);
        simd::set_path_override(None);
        assert_bits_eq(&out_scalar, &out_vector, &format!("{fmt:?} kv dequant"));
    }
}
