//! Integration: the full PTQ pipeline (calibrate → quantize → eval) on
//! trained artifacts, cross-checking the Rust and Python calibrations.
//! Skips gracefully when artifacts are absent.

use arcquant::baselines::Method;
use arcquant::formats::Format;
use arcquant::model::EngineMode;
use arcquant::report::{Ctx, EvalBudget};
use arcquant::runtime::ModelBundle;

fn ctx() -> Option<Ctx> {
    let root = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if !std::path::Path::new(&format!("{root}/manifest.json")).exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Ctx::new(root, EvalBudget::quick()))
}

#[test]
fn trained_model_beats_untrained_ppl() {
    let Some(ctx) = ctx() else { return };
    let (engine, _) = ctx.engine("llama8b-sim", EngineMode::Fp32).unwrap();
    let stream = ctx.eval_stream("wiki").unwrap();
    let r = arcquant::eval::perplexity(&engine, &stream, 64, 6);
    // corpus entropy floor is far below vocab=256; training must have
    // brought PPL well under 100 (≈18-25 at 350 steps).
    assert!(r.ppl < 100.0, "trained PPL {}", r.ppl);
    assert!(r.ppl > 1.0);
}

#[test]
fn method_ordering_on_trained_model() {
    // The paper's qualitative story on a real trained model:
    // FP16 <= ARCQuant <= RTN in PPL, and ARCQuant close to W4A8.
    let Some(ctx) = ctx() else { return };
    let fp = ctx.eval_row("llama8b-sim", None).unwrap();
    let arc = ctx
        .eval_row(
            "llama8b-sim",
            Some(Method::ArcQuant { fmt: Format::Nvfp4, max_s: Some(512) }),
        )
        .unwrap();
    let rtn = ctx
        .eval_row("llama8b-sim", Some(Method::Rtn { fmt: Format::Nvfp4 }))
        .unwrap();
    assert!(fp.ppl <= arc.ppl * 1.02, "fp {} vs arc {}", fp.ppl, arc.ppl);
    assert!(
        arc.ppl <= rtn.ppl * 1.02,
        "arc {} vs rtn {}",
        arc.ppl,
        rtn.ppl
    );
}

#[test]
fn rust_and_python_calibrations_agree_on_outliers() {
    // Both pipelines implement §3.2; their per-site top-16 channel sets
    // should overlap heavily (not exactly: different windows).
    let Some(ctx) = ctx() else { return };
    let (cfg, w) = ctx.model("llama8b-sim").unwrap();
    let stream = ctx.corpus("wiki").unwrap();
    let rust_cal = arcquant::calib::run_calibration(&cfg, &w, &stream, 6, 64).unwrap();
    let py = ModelBundle::load(&ctx.artifacts, "llama8b-sim").unwrap();
    let mut checked = 0;
    let mut total_overlap = 0usize;
    for (site, plan) in &py.plans {
        let Some(rc) = rust_cal.sites.get(site) else { continue };
        let rust_plan = arcquant::quant::LayerPlan::from_calibration(
            &rc.col_absmax,
            Format::Nvfp4,
        );
        let py_top: std::collections::BTreeSet<usize> =
            plan.perm[..16].iter().map(|&v| v as usize).collect();
        let rust_top: std::collections::BTreeSet<usize> =
            rust_plan.perm.idx[..16].iter().copied().collect();
        let overlap = py_top.intersection(&rust_top).count();
        // Channels beyond the few dominant outliers have near-equal
        // magnitudes, so exact top-16 ranks are window-dependent; require
        // a per-site floor and a strong average overlap.
        assert!(
            overlap >= 4,
            "{site}: top-16 overlap only {overlap} (py {py_top:?} vs rust {rust_top:?})"
        );
        total_overlap += overlap;
        checked += 1;
    }
    assert!(checked >= 8, "checked only {checked} sites");
    let mean = total_overlap as f64 / checked as f64;
    assert!(mean >= 7.0, "mean top-16 overlap {mean:.1} < 7");
}

#[test]
fn coder_model_better_on_code_than_base() {
    // Domain fine-tuning sanity: the coder model must beat the base model
    // on the code corpus PPL.
    let Some(ctx) = ctx() else { return };
    let (coder, _) = ctx.engine("coder7b-sim", EngineMode::Fp32).unwrap();
    let (base, _) = ctx.engine("llama8b-sim", EngineMode::Fp32).unwrap();
    let code = ctx.eval_stream("code").unwrap();
    let p_coder = arcquant::eval::perplexity(&coder, &code, 64, 4).ppl;
    let p_base = arcquant::eval::perplexity(&base, &code, 64, 4).ppl;
    assert!(
        p_coder < p_base,
        "coder {p_coder} not better than base {p_base} on code"
    );
}
