//! Format-conformance harness: every registered codec runs the shared
//! correctness spine in `arcquant::formats::conformance` — pack/decode
//! roundtrips, per-element reconstruction bounds, packed-GEMM differential
//! equality, and quantize-once KV replay — plus the SIMD fallback and
//! empirical error-bound pins for the RaZeR / Four-over-Six codecs.
//!
//! Own integration binary because the SIMD path override is
//! process-global (same reasoning as `integration_determinism`): the
//! forced-path tests serialize on a local mutex so they cannot race.

use arcquant::formats::conformance::{
    check_error_bound, check_gemm_differential, check_kv_replay, check_roundtrip,
    half_max_gap, registered_formats,
};
use arcquant::formats::{Format, RowQuantizer};
use arcquant::quant::dual_stage_reconstruct;
use arcquant::tensor::simd::{self, SimdPath};
use arcquant::tensor::{matmul_nt_packed, Mat};
use arcquant::util::prop::gens::outlier_mat;
use arcquant::util::Prng;
use std::sync::Mutex;

/// Serializes the tests that flip the process-global SIMD path override.
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

// ---------------------------------------------------------------------------
// The conformance spine, over every registered codec
// ---------------------------------------------------------------------------

#[test]
fn all_codecs_roundtrip_bit_exact() {
    for fmt in registered_formats() {
        check_roundtrip(fmt).unwrap_or_else(|e| panic!("{fmt:?}: {e}"));
    }
}

#[test]
fn all_codecs_reconstruct_within_half_gap_bound() {
    for fmt in registered_formats() {
        assert!(half_max_gap(fmt) > 0.0, "{fmt:?}: degenerate half-gap");
        check_error_bound(fmt).unwrap_or_else(|e| panic!("{fmt:?}: {e}"));
    }
}

#[test]
fn all_codecs_packed_gemm_matches_dequantized_gemm() {
    for fmt in registered_formats() {
        check_gemm_differential(fmt).unwrap_or_else(|e| panic!("{fmt:?}: {e}"));
    }
}

#[test]
fn all_codecs_replay_kv_bit_identically() {
    for fmt in registered_formats() {
        check_kv_replay(fmt).unwrap_or_else(|e| panic!("{fmt:?}: {e}"));
    }
}

// ---------------------------------------------------------------------------
// SIMD dispatch fallback (satellite: no silent wrong-table decode)
// ---------------------------------------------------------------------------

/// A RaZeR matrix whose second block is dominated by +5.0 values — every
/// one encodes as the remapped code 8. An E2M1 magnitude-shuffle decode
/// (sign from nibble bit 3) would read those back as `-0.0`.
fn razer_code8_mat() -> Mat {
    let g = Format::Razer4.group();
    Mat::from_fn(2, 2 * g, |r, c| {
        if c == 0 {
            2688.0 // absmax anchor → tensor_scale = 1.0
        } else if c >= g {
            if (r + c) % 4 == 0 {
                6.0
            } else {
                5.0
            }
        } else {
            0.0
        }
    })
}

#[test]
fn forced_avx2_razer_decode_takes_scalar_arm_not_e2m1_shuffle() {
    // The dispatch fix under test: kernels must key on
    // `simd::path_for_encoding`, not the global path. With the override
    // forced to AVX2, RaZeR decodes must still route the scalar arm and
    // read code 8 as +5.0 — bit-identical to the forced-scalar decode.
    // On hosts without AVX2 the override degrades to scalar and this
    // pins the same equality trivially.
    let _guard = OVERRIDE_LOCK.lock().unwrap();
    let g = Format::Razer4.group();
    let m = razer_code8_mat();
    let q = RowQuantizer::new(Format::Razer4);
    let qm = q.quantize(&m);
    // the probe really exercises the remapped code
    assert!(
        qm.row_codes(0).iter().any(|&b| b & 0x0F == 8 || b >> 4 == 8),
        "probe matrix emitted no code-8 nibbles"
    );

    simd::set_path_override(Some(SimdPath::Scalar));
    let scalar = qm.dequantize();
    simd::set_path_override(Some(SimdPath::Avx2));
    let forced = qm.dequantize();
    simd::set_path_override(None);

    let bits = |m: &Mat| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&scalar), bits(&forced), "RaZeR decode differs across forced paths");
    // and the decode is *right*, not just consistent: code 8 → +5.0
    let s = qm.block_scale(0, 1);
    for c in g..2 * g {
        if (c % 4) != 0 {
            assert_eq!(forced.at(0, c), 5.0 * s, "col {c}: code 8 misdecoded");
            assert!(forced.at(0, c) > 0.0, "col {c}: sign flipped (E2M1 table?)");
        }
    }
}

#[test]
fn forced_avx2_razer_gemm_matches_scalar_bit_exact() {
    let _guard = OVERRIDE_LOCK.lock().unwrap();
    let mut rng = Prng::new(0x4A4C3);
    let x = razer_code8_mat();
    let mut w = outlier_mat(&mut rng, 5, x.cols);
    for c in 0..w.cols {
        *w.at_mut(2, c) = 5.0; // weight rows hit code 8 too
    }
    let q = RowQuantizer::new(Format::Razer4);
    let (qa, qb) = (q.quantize(&x), q.quantize(&w));
    simd::set_path_override(Some(SimdPath::Scalar));
    let y_s = matmul_nt_packed(&qa, &qb);
    simd::set_path_override(Some(SimdPath::Avx2));
    let y_v = matmul_nt_packed(&qa, &qb);
    simd::set_path_override(None);
    let bits = |m: &Mat| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&y_s), bits(&y_v), "RaZeR packed GEMM differs across forced paths");
}

// ---------------------------------------------------------------------------
// Empirical error bounds (satellite: dual-stage vs MXFP8, RaZeR/FoS gains)
// ---------------------------------------------------------------------------

/// Adversarial activation batch: unit normals with every 97th channel
/// boosted 80× — the outlier pattern that stresses a shared block scale.
fn adversarial_mat(rows: usize, cols: usize) -> Mat {
    let mut rng = Prng::new(0x4A4C4);
    Mat::from_fn(rows, cols, |_, c| {
        let v = rng.normal();
        if c % 97 == 3 {
            v * 80.0
        } else {
            v
        }
    })
}

fn max_abs_err(x: &Mat, y: &[f32]) -> f32 {
    x.data
        .iter()
        .zip(y)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0.0f32, f32::max)
}

#[test]
fn dual_stage_nvfp4_worst_case_error_comparable_to_mxfp8() {
    // The paper's Table 1 claim, as a worst-case (not mean) bound on
    // adversarial outlier blocks: two NVFP4 passes (primary + residual)
    // reconstruct within a small factor of one MXFP8 pass, and far below
    // a single NVFP4 pass.
    let x = adversarial_mat(4, 97 * 4);
    let dual: Vec<f32> = (0..x.rows).flat_map(|r| {
        dual_stage_reconstruct(x.row(r), Format::Nvfp4)
    }).collect();
    let single = RowQuantizer::new(Format::Nvfp4).qdq_mat(&x);
    let mx8 = RowQuantizer::new(Format::Mxfp8E4M3).qdq_mat(&x);
    let dual_max = max_abs_err(&x, &dual);
    let single_max = max_abs_err(&x, &single.data);
    let mx8_max = max_abs_err(&x, &mx8.data);
    assert!(
        dual_max <= 4.0 * mx8_max,
        "dual-stage NVFP4 worst-case {dual_max} not MXFP8-comparable ({mx8_max})"
    );
    assert!(
        dual_max < 0.5 * single_max,
        "dual-stage {dual_max} should be well below single-stage {single_max}"
    );
}

#[test]
fn razer_and_four_over_six_strictly_improve_nvfp4_worst_case() {
    // Positive-heavy blocks sitting in E2M1's 4→6 hole: the +5.0 bulk
    // costs plain NVFP4 a full unit per element. RaZeR represents it
    // exactly (code 8). The anchor block (amax 24) keeps the tensor scale
    // above the 5.0-blocks' own amax so Four-over-Six's amax/4 candidate
    // doesn't saturate E4M3 — it lands on a denser rung and wins.
    let g = Format::Nvfp4.group();
    let m = Mat::from_fn(2, 3 * g, |_, c| {
        if c < g {
            if c == 0 {
                24.0
            } else {
                0.0
            }
        } else if c % g == 0 {
            6.0
        } else {
            5.0
        }
    });
    let nv = RowQuantizer::new(Format::Nvfp4).qdq_mat(&m);
    let rz = RowQuantizer::new(Format::Razer4).qdq_mat(&m);
    let fos = RowQuantizer::new(Format::FourOverSix).qdq_mat(&m);
    let nv_max = max_abs_err(&m, &nv.data);
    let rz_max = max_abs_err(&m, &rz.data);
    let fos_max = max_abs_err(&m, &fos.data);
    assert!(nv_max > 0.9, "probe should cost NVFP4 ~1.0/elem, got {nv_max}");
    assert!(rz_max < nv_max, "RaZeR {rz_max} must beat NVFP4 {nv_max}");
    assert!(fos_max < nv_max, "Four-over-Six {fos_max} must beat NVFP4 {nv_max}");
    // RaZeR nails this grid exactly (up to tensor-scale rounding)
    assert!(rz_max < 1e-3, "RaZeR should be near-exact on its own grid: {rz_max}");
}

#[test]
fn razer_and_four_over_six_never_regress_nvfp4_on_random_batches() {
    // Same element budget, same scale rule family — on generic outlier
    // batches the new codecs' MSE must stay ≤ NVFP4's (RaZeR only adds a
    // representable point; Four-over-Six only switches scale when its
    // measured error is lower).
    let mut rng = Prng::new(0x4A4C5);
    for _ in 0..8 {
        let x = outlier_mat(&mut rng, 4, 128);
        let mse = |fmt: Format| -> f64 {
            let y = RowQuantizer::new(fmt).qdq_mat(&x);
            x.data
                .iter()
                .zip(&y.data)
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                / x.data.len() as f64
        };
        let nv = mse(Format::Nvfp4);
        assert!(mse(Format::Razer4) <= nv + 1e-12, "RaZeR regressed vs NVFP4");
        assert!(mse(Format::FourOverSix) <= nv + 1e-12, "4/6 regressed vs NVFP4");
    }
}
