//! Figure 8a bench: augmented-GEMM latency vs S on the host, plus the
//! calibrated Blackwell cost-model series. Latency must be linear in K+S.
//!
//! Also records the packed-execution perf trajectory into
//! `BENCH_gemm_packed.json`:
//!
//! * packed-vs-QDQ forward comparison at paper shapes (K=4096,
//!   S ∈ {0, 128, 256}): tokens/s and bytes-moved per forward;
//! * **kernel v1-vs-v2**: the pre-v2 one-row-at-a-time kernel
//!   ([`matmul_nt_packed_ref`]) against the register-tiled v2 kernel
//!   ([`matmul_nt_packed`]) on identical packed operands, at
//!   the K=4096 shapes for both prefill (n=16) and decode (n=1), with
//!   the geometric-mean speedup — the acceptance series for the v2
//!   rewrite;
//! * **SIMD dispatch** (`kernel_simd`): the v2 kernel forced scalar vs
//!   the best runtime-detected path (AVX2 shuffle decode — see
//!   `tensor::simd`) via the in-process override, same operands, with
//!   the geomean — the acceptance series for the explicit-SIMD layer.
//!
//! Emits stable `GATE key value` lines that `scripts/bench_gate.py`
//! floors in CI (printed in smoke mode too).
//!
//! `ARCQUANT_BENCH_SMOKE=1` shrinks every shape and skips the JSON
//! rewrite — CI uses it to catch kernel-routing panics cheaply.

use arcquant::costmodel::{gemm_us, GemmPath, Gpu};
use arcquant::formats::{Format, RowQuantizer};
use arcquant::quant::{ArcQuantLinear, LayerPlan, PackedArcLinear, Permutation};
use arcquant::tensor::simd::{self, SimdPath};
use arcquant::tensor::{matmul_nt, matmul_nt_packed, matmul_nt_packed_ref, Mat};
use arcquant::util::bench::{smoke_mode, Bencher};
use arcquant::util::json::Json;
use arcquant::util::pool;
use arcquant::util::prop::gens::outlier_mat;
use arcquant::util::stats;
use arcquant::util::Prng;

/// Per-codec kernel series: every 4-bit element codec the packed path
/// serves as an activation/weight format (NVFP4 baseline plus the
/// RaZeR and Four-over-Six variants) through the v1 reference and v2
/// tiled kernels on identical packed operands. RaZeR pins the scalar
/// dispatch arm (`simd::path_for_encoding` — the AVX2 shuffle would
/// decode its code 8 as `-0.0`), so its row tracks the scalar-only
/// cost the codec pays for reclaiming the redundant zero; the other
/// rows ride the best detected path. Prints one
/// `GATE gemm_kernel_v2_over_v1_<fmt>` row per codec so a dispatch
/// misroute that tanks a single format cannot hide inside the
/// all-format geomean.
fn bench_format_kernels(b: &Bencher) -> Vec<Json> {
    let (n, k, m) = if smoke_mode() { (4usize, 256usize, 32usize) } else { (16usize, 4096usize, 256usize) };
    let mut rng = Prng::new(7);
    let x = outlier_mat(&mut rng, n, k);
    let mut w = Mat::zeros(m, k);
    w.fill_random_normal(&mut rng, 0.4);
    let mut rows: Vec<Json> = Vec::new();
    println!("# per-codec packed kernel (N={n}, K={k}, M={m})");
    for (label, fmt) in [
        ("nvfp4", Format::Nvfp4),
        ("razer", Format::Razer4),
        ("fouroversix", Format::FourOverSix),
    ] {
        let rq = RowQuantizer::new(fmt);
        let qx = rq.quantize(&x);
        let qw = rq.quantize(&w);
        let r_v1 = b.run(&format!("kernel_v1_{label}_k{k}"), || {
            matmul_nt_packed_ref(&qx, &qw)
        });
        let r_v2 = b.run(&format!("kernel_v2_{label}_k{k}"), || {
            matmul_nt_packed(&qx, &qw)
        });
        let speedup = r_v1.median_us / r_v2.median_us;
        println!(
            "#   {label}: v1 {:.1}us v2 {:.1}us ({speedup:.2}x)",
            r_v1.median_us, r_v2.median_us
        );
        println!("GATE gemm_kernel_v2_over_v1_{label} {speedup:.4}");
        let mut row = Json::obj();
        row.set("format", Json::Str(fmt.name().into()))
            .set("n", Json::Num(n as f64))
            .set("k", Json::Num(k as f64))
            .set("m", Json::Num(m as f64))
            .set("v1_median_us", Json::Num(r_v1.median_us))
            .set("v2_median_us", Json::Num(r_v2.median_us))
            .set("speedup_v2_over_v1", Json::Num(speedup));
        rows.push(row);
    }
    rows
}

/// Packed-vs-QDQ forward + kernel v1-vs-v2 at paper shapes →
/// BENCH_gemm_packed.json (skipped in smoke mode).
fn bench_packed_vs_qdq(b: &Bencher) {
    let (n, k, m) = if smoke_mode() { (4usize, 256usize, 32usize) } else { (16usize, 4096usize, 256usize) };
    let s_list: &[usize] = if smoke_mode() { &[0, 32] } else { &[0, 128, 256] };
    let mut rng = Prng::new(1);
    let mut rows: Vec<Json> = Vec::new();
    let mut kernel_rows: Vec<Json> = Vec::new();
    let mut simd_rows: Vec<Json> = Vec::new();
    let mut speedups: Vec<f64> = Vec::new();
    let mut simd_speedups: Vec<f64> = Vec::new();
    // the best path the dispatch can reach on this host (override never
    // forces past detection, so this is what Some(Avx2) resolves to)
    let best_path = if simd::avx2_available() { "avx2" } else { "scalar" };
    println!("# packed vs QDQ ARCQuant forward (N={n}, K={k}, M={m})");
    for &s in s_list {
        let x = outlier_mat(&mut rng, n, k);
        let mut w = Mat::zeros(m, k);
        w.fill_random_normal(&mut rng, 0.4);
        let plan = LayerPlan {
            perm: Permutation::identity(k),
            s,
            fmt: Format::Nvfp4,
        };
        let qdq = ArcQuantLinear::prepare(&w, plan.clone());
        let packed = PackedArcLinear::prepare(&w, plan).expect("aligned");

        let r_qdq = b.run(&format!("gemm_aug_qdq_k{k}_s{s}"), || qdq.forward(&x));
        let r_packed =
            b.run(&format!("gemm_aug_packed_k{k}_s{s}"), || packed.forward(&x));

        // Kernel-level v1 vs v2 on identical packed operands: prefill
        // shape (n rows) and single-token decode shape (1 row).
        for (label, rows_n) in [("prefill", n), ("decode", 1usize)] {
            let xs = if rows_n == n {
                x.clone()
            } else {
                Mat::from_vec(rows_n, k, x.row(0).to_vec())
            };
            let aug = packed.quantizer.quantize_activations_packed(&xs);
            let r_v1 = b.run(&format!("kernel_v1_{label}_k{k}_s{s}"), || {
                matmul_nt_packed_ref(&aug.qm, &packed.w_packed)
            });
            let r_v2 = b.run(&format!("kernel_v2_{label}_k{k}_s{s}"), || {
                matmul_nt_packed(&aug.qm, &packed.w_packed)
            });
            let speedup = r_v1.median_us / r_v2.median_us;
            speedups.push(speedup);
            println!(
                "#   kernel {label} s={s}: v1 {:.1}us v2 {:.1}us ({speedup:.2}x)",
                r_v1.median_us, r_v2.median_us
            );
            let mut kr = Json::obj();
            kr.set("shape", Json::Str(label.into()))
                .set("n", Json::Num(rows_n as f64))
                .set("k", Json::Num(k as f64))
                .set("m", Json::Num(m as f64))
                .set("s", Json::Num(s as f64))
                .set("v1_median_us", Json::Num(r_v1.median_us))
                .set("v2_median_us", Json::Num(r_v2.median_us))
                .set("speedup_v2_over_v1", Json::Num(speedup));
            kernel_rows.push(kr);

            // SIMD dispatch series: the same v2 kernel forced scalar vs
            // the best detected path (both via the in-process override;
            // outputs are bit-identical, only the arm differs).
            simd::set_path_override(Some(SimdPath::Scalar));
            let r_scalar = b.run(&format!("kernel_simd_scalar_{label}_k{k}_s{s}"), || {
                matmul_nt_packed(&aug.qm, &packed.w_packed)
            });
            simd::set_path_override(Some(SimdPath::Avx2));
            let r_best = b.run(&format!("kernel_simd_{best_path}_{label}_k{k}_s{s}"), || {
                matmul_nt_packed(&aug.qm, &packed.w_packed)
            });
            simd::set_path_override(None);
            let sp = r_scalar.median_us / r_best.median_us;
            simd_speedups.push(sp);
            println!(
                "#   kernel simd {label} s={s}: scalar {:.1}us {best_path} {:.1}us ({sp:.2}x)",
                r_scalar.median_us, r_best.median_us
            );
            let mut sr = Json::obj();
            sr.set("shape", Json::Str(label.into()))
                .set("n", Json::Num(rows_n as f64))
                .set("k", Json::Num(k as f64))
                .set("m", Json::Num(m as f64))
                .set("s", Json::Num(s as f64))
                .set("scalar_median_us", Json::Num(r_scalar.median_us))
                .set("best_median_us", Json::Num(r_best.median_us))
                .set("best_path", Json::Str(best_path.into()))
                .set("speedup_best_over_scalar", Json::Num(sp));
            simd_rows.push(sr);
        }

        // Bytes moved per forward, weight side + activation side. QDQ
        // streams f32 for both; packed streams codes + block scales.
        let w_bytes_qdq = (m * (k + s) * 4) as u64;
        let a_bytes_qdq = (n * (k + s) * 4) as u64;
        let w_bytes_packed = packed.weight_bytes();
        let a_bytes_packed = Format::Nvfp4.storage_bytes(n, k + s);
        let tok_s = |median_us: f64| n as f64 / (median_us * 1e-6);

        let ratio = w_bytes_qdq as f64 / w_bytes_packed as f64;
        println!(
            "#   s={s}: weight bytes packed {w_bytes_packed} vs f32 {w_bytes_qdq} ({ratio:.1}x), \
             tokens/s packed {:.1} vs qdq {:.1}",
            tok_s(r_packed.median_us),
            tok_s(r_qdq.median_us)
        );
        // Acceptance: packed weight footprint ≤ 1/6 of the f32 path.
        assert!(
            w_bytes_packed as f64 <= w_bytes_qdq as f64 / 6.0,
            "packed weights not ≤ f32/6 at s={s}"
        );

        let mut row = Json::obj();
        row.set("n", Json::Num(n as f64))
            .set("k", Json::Num(k as f64))
            .set("m", Json::Num(m as f64))
            .set("s", Json::Num(s as f64));
        let mut qj = Json::obj();
        qj.set("median_us", Json::Num(r_qdq.median_us))
            .set("tokens_per_s", Json::Num(tok_s(r_qdq.median_us)))
            .set("weight_bytes", Json::Num(w_bytes_qdq as f64))
            .set("activation_bytes", Json::Num(a_bytes_qdq as f64));
        let mut pj = Json::obj();
        pj.set("median_us", Json::Num(r_packed.median_us))
            .set("tokens_per_s", Json::Num(tok_s(r_packed.median_us)))
            .set("weight_bytes", Json::Num(w_bytes_packed as f64))
            .set("activation_bytes", Json::Num(a_bytes_packed as f64));
        row.set("qdq", qj)
            .set("packed", pj)
            .set("weight_ratio_f32_over_packed", Json::Num(ratio));
        rows.push(row);
    }
    let geomean = stats::geomean(&speedups);
    let simd_geomean = stats::geomean(&simd_speedups);
    println!("# kernel geomean speedup v2/v1: {geomean:.2}x");
    println!("# kernel simd geomean speedup {best_path}/scalar: {simd_geomean:.2}x");
    // GATE lines: stable key/value pairs scripts/bench_gate.py parses —
    // printed in smoke mode too, so CI can sanity-floor every run.
    println!("GATE gemm_kernel_geomean_v2_over_v1 {geomean:.4}");
    println!("GATE gemm_simd_geomean_best_over_scalar {simd_geomean:.4}");
    println!("GATE gemm_simd_best_path {best_path}");

    // per-codec series prints its own GATE rows (smoke mode included)
    let format_rows = bench_format_kernels(b);

    if smoke_mode() {
        println!("# smoke mode: BENCH_gemm_packed.json not rewritten");
        return;
    }
    // Keep the top-level schema identical to the committed baseline so
    // regeneration diffs show perf deltas, not schema churn.
    let mut prov = Json::obj();
    prov.set(
        "source",
        Json::Str("cargo bench --bench bench_gemm_aug (in-tree harness)".into()),
    )
    .set("threads", Json::Num(pool::num_threads() as f64))
    .set("simd_best_path", Json::Str(best_path.into()));
    let mut out = Json::obj();
    out.set("bench", Json::Str("gemm_packed".into()))
        .set("provenance", prov)
        .set("shapes", Json::Arr(rows))
        .set("kernel", Json::Arr(kernel_rows))
        .set("kernel_geomean_speedup_v2_over_v1", Json::Num(geomean))
        .set("kernel_simd", Json::Arr(simd_rows))
        .set("kernel_simd_geomean_speedup", Json::Num(simd_geomean))
        .set("kernel_formats", Json::Arr(format_rows));
    let path = "BENCH_gemm_packed.json";
    match std::fs::write(path, out.dump()) {
        Ok(()) => println!("# wrote {path}"),
        Err(e) => {
            // a failed trajectory rewrite must fail the run, or the
            // runner would report success over stale numbers
            eprintln!("# could not write {path}: {e}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let b = if smoke_mode() { Bencher::smoke() } else { Bencher::default() };
    let (n, k, m) = if smoke_mode() { (8usize, 128usize, 32usize) } else { (64usize, 1024usize, 256usize) };
    let s_list: &[usize] = if smoke_mode() { &[0, 32] } else { &[0, 128, 256, 512, 1024] };
    let mut rng = Prng::new(0);
    println!("# host GEMM (N={n}, K={k}+S, M={m}) + modeled RTX 5090 GEMM (8192x4096x4096)");
    let mut prev = 0.0;
    for &s in s_list {
        let mut x = Mat::zeros(n, k + s);
        let mut w = Mat::zeros(m, k + s);
        x.fill_random_normal(&mut rng, 1.0);
        w.fill_random_normal(&mut rng, 1.0);
        let r = b.run(&format!("gemm_aug_host_s{s}"), || matmul_nt(&x, &w));
        let modeled = gemm_us(Gpu::Rtx5090, GemmPath::Nvfp4Aug { s }, 8192, 4096, 4096);
        println!("MODEL gemm_aug_5090_s{s} latency_us={modeled:.1}");
        if s > 0 {
            let delta = r.median_us - prev;
            println!("#   host delta vs previous S: {delta:+.1}us (linear-in-S check)");
        }
        prev = r.median_us;
    }
    // comparison points (Fig 8a inset): W4A8 and MXFP8 modeled
    for (name, path) in [
        ("nvfp4", GemmPath::Nvfp4),
        ("w4a8", GemmPath::W4A8),
        ("mxfp8", GemmPath::Mxfp8),
        ("fp16", GemmPath::Fp16),
    ] {
        let t = gemm_us(Gpu::Rtx5090, path, 8192, 4096, 4096);
        println!("MODEL gemm_{name}_5090 latency_us={t:.1}");
    }

    let kb = if smoke_mode() { Bencher::smoke() } else { Bencher::quick() };
    bench_packed_vs_qdq(&kb);
}
