//! Figure 8a bench: augmented-GEMM latency vs S on the host, plus the
//! calibrated Blackwell cost-model series. Latency must be linear in K+S.
//!
//! Also records the packed-vs-QDQ execution comparison at paper shapes
//! (K=4096, S ∈ {0, 128, 256}) into `BENCH_gemm_packed.json`: tokens/s
//! and bytes-moved per forward for both paths, so the perf trajectory of
//! the packed datapath is tracked across PRs.

use arcquant::costmodel::{gemm_us, GemmPath, Gpu};
use arcquant::formats::Format;
use arcquant::quant::{ArcQuantLinear, LayerPlan, PackedArcLinear, Permutation};
use arcquant::tensor::{matmul_nt, Mat};
use arcquant::util::bench::Bencher;
use arcquant::util::json::Json;
use arcquant::util::prop::gens::outlier_mat;
use arcquant::util::Prng;

/// Packed-vs-QDQ forward at paper shapes → BENCH_gemm_packed.json.
fn bench_packed_vs_qdq(b: &Bencher) {
    let (n, k, m) = (16usize, 4096usize, 256usize);
    let mut rng = Prng::new(1);
    let mut rows: Vec<Json> = Vec::new();
    println!("# packed vs QDQ ARCQuant forward (N={n}, K={k}, M={m})");
    for s in [0usize, 128, 256] {
        let x = outlier_mat(&mut rng, n, k);
        let mut w = Mat::zeros(m, k);
        w.fill_random_normal(&mut rng, 0.4);
        let plan = LayerPlan {
            perm: Permutation::identity(k),
            s,
            fmt: Format::Nvfp4,
        };
        let qdq = ArcQuantLinear::prepare(&w, plan.clone());
        let packed = PackedArcLinear::prepare(&w, plan).expect("aligned");

        let r_qdq = b.run(&format!("gemm_aug_qdq_k{k}_s{s}"), || qdq.forward(&x));
        let r_packed =
            b.run(&format!("gemm_aug_packed_k{k}_s{s}"), || packed.forward(&x));

        // Bytes moved per forward, weight side + activation side. QDQ
        // streams f32 for both; packed streams codes + block scales.
        let w_bytes_qdq = (m * (k + s) * 4) as u64;
        let a_bytes_qdq = (n * (k + s) * 4) as u64;
        let w_bytes_packed = packed.weight_bytes();
        let a_bytes_packed = Format::Nvfp4.storage_bytes(n, k + s);
        let tok_s = |median_us: f64| n as f64 / (median_us * 1e-6);

        let ratio = w_bytes_qdq as f64 / w_bytes_packed as f64;
        println!(
            "#   s={s}: weight bytes packed {w_bytes_packed} vs f32 {w_bytes_qdq} ({ratio:.1}x), \
             tokens/s packed {:.1} vs qdq {:.1}",
            tok_s(r_packed.median_us),
            tok_s(r_qdq.median_us)
        );
        // Acceptance: packed weight footprint ≤ 1/6 of the f32 path.
        assert!(
            w_bytes_packed as f64 <= w_bytes_qdq as f64 / 6.0,
            "packed weights not ≤ f32/6 at s={s}"
        );

        let mut row = Json::obj();
        row.set("n", Json::Num(n as f64))
            .set("k", Json::Num(k as f64))
            .set("m", Json::Num(m as f64))
            .set("s", Json::Num(s as f64));
        let mut qj = Json::obj();
        qj.set("median_us", Json::Num(r_qdq.median_us))
            .set("tokens_per_s", Json::Num(tok_s(r_qdq.median_us)))
            .set("weight_bytes", Json::Num(w_bytes_qdq as f64))
            .set("activation_bytes", Json::Num(a_bytes_qdq as f64));
        let mut pj = Json::obj();
        pj.set("median_us", Json::Num(r_packed.median_us))
            .set("tokens_per_s", Json::Num(tok_s(r_packed.median_us)))
            .set("weight_bytes", Json::Num(w_bytes_packed as f64))
            .set("activation_bytes", Json::Num(a_bytes_packed as f64));
        row.set("qdq", qj)
            .set("packed", pj)
            .set("weight_ratio_f32_over_packed", Json::Num(ratio));
        rows.push(row);
    }
    let mut out = Json::obj();
    out.set("bench", Json::Str("gemm_packed".into()))
        .set("shapes", Json::Arr(rows));
    let path = "BENCH_gemm_packed.json";
    match std::fs::write(path, out.dump()) {
        Ok(()) => println!("# wrote {path}"),
        Err(e) => eprintln!("# could not write {path}: {e}"),
    }
}

fn main() {
    let b = Bencher::default();
    let (n, k, m) = (64usize, 1024usize, 256usize);
    let mut rng = Prng::new(0);
    println!("# host GEMM (N={n}, K=1024+S, M={m}) + modeled RTX 5090 GEMM (8192x4096x4096)");
    let mut prev = 0.0;
    for s in [0usize, 128, 256, 512, 1024] {
        let mut x = Mat::zeros(n, k + s);
        let mut w = Mat::zeros(m, k + s);
        x.fill_random_normal(&mut rng, 1.0);
        w.fill_random_normal(&mut rng, 1.0);
        let r = b.run(&format!("gemm_aug_host_s{s}"), || matmul_nt(&x, &w));
        let modeled = gemm_us(Gpu::Rtx5090, GemmPath::Nvfp4Aug { s }, 8192, 4096, 4096);
        println!("MODEL gemm_aug_5090_s{s} latency_us={modeled:.1}");
        if s > 0 {
            let delta = r.median_us - prev;
            println!("#   host delta vs previous S: {delta:+.1}us (linear-in-S check)");
        }
        prev = r.median_us;
    }
    // comparison points (Fig 8a inset): W4A8 and MXFP8 modeled
    for (name, path) in [
        ("nvfp4", GemmPath::Nvfp4),
        ("w4a8", GemmPath::W4A8),
        ("mxfp8", GemmPath::Mxfp8),
        ("fp16", GemmPath::Fp16),
    ] {
        let t = gemm_us(Gpu::Rtx5090, path, 8192, 4096, 4096);
        println!("MODEL gemm_{name}_5090 latency_us={t:.1}");
    }

    bench_packed_vs_qdq(&Bencher::quick());
}
