//! Figure 8a bench: augmented-GEMM latency vs S on the host, plus the
//! calibrated Blackwell cost-model series. Latency must be linear in K+S.

use arcquant::costmodel::{gemm_us, GemmPath, Gpu};
use arcquant::tensor::{matmul_nt, Mat};
use arcquant::util::bench::Bencher;
use arcquant::util::Prng;

fn main() {
    let b = Bencher::default();
    let (n, k, m) = (64usize, 1024usize, 256usize);
    let mut rng = Prng::new(0);
    println!("# host GEMM (N={n}, K=1024+S, M={m}) + modeled RTX 5090 GEMM (8192x4096x4096)");
    let mut prev = 0.0;
    for s in [0usize, 128, 256, 512, 1024] {
        let mut x = Mat::zeros(n, k + s);
        let mut w = Mat::zeros(m, k + s);
        x.fill_random_normal(&mut rng, 1.0);
        w.fill_random_normal(&mut rng, 1.0);
        let r = b.run(&format!("gemm_aug_host_s{s}"), || matmul_nt(&x, &w));
        let modeled = gemm_us(Gpu::Rtx5090, GemmPath::Nvfp4Aug { s }, 8192, 4096, 4096);
        println!("MODEL gemm_aug_5090_s{s} latency_us={modeled:.1}");
        if s > 0 {
            let delta = r.median_us - prev;
            println!("#   host delta vs previous S: {delta:+.1}us (linear-in-S check)");
        }
        prev = r.median_us;
    }
    // comparison points (Fig 8a inset): W4A8 and MXFP8 modeled
    for (name, path) in [
        ("nvfp4", GemmPath::Nvfp4),
        ("w4a8", GemmPath::W4A8),
        ("mxfp8", GemmPath::Mxfp8),
        ("fp16", GemmPath::Fp16),
    ] {
        let t = gemm_us(Gpu::Rtx5090, path, 8192, 4096, 4096);
        println!("MODEL gemm_{name}_5090 latency_us={t:.1}");
    }
}
