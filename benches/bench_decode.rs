//! Decode-throughput bench: ArcPacked vs Fp32 (and the QDQ ArcQuant
//! reference) batched decode over per-sequence KV caches, at batch sizes
//! {1, 4, 8} — the serving-side counterpart of `bench_gemm_aug`'s
//! kernel-level comparison. Emits `BENCH_decode.json` with tokens/s per
//! (variant, batch) plus the KV page-manager accounting, so the decode
//! trajectory of the packed datapath is tracked across PRs.
//!
//! Method: per sample, prefill `batch` fresh prompts (untimed), then time
//! `STEPS` consecutive `decode_batch` ticks and report
//! `batch · STEPS / elapsed`. Median over samples. Fixed work per timing
//! window (instead of the adaptive `Bencher`) because every decode tick
//! grows the caches — throughput at unbounded iteration counts would
//! measure ever-longer attention spans.

use arcquant::baselines::Method;
use arcquant::coordinator::kvcache::KvPageManager;
use arcquant::formats::Format;
use arcquant::model::{sampling, Engine, EngineMode, KvCache, ModelConfig, Weights};
use arcquant::util::json::Json;
use arcquant::util::{stats, Timer};
use std::collections::BTreeMap;

const PROMPT_LEN: usize = 16;
const STEPS: usize = 16;
const SAMPLES: usize = 5;

fn decode_tok_s(engine: &Engine, batch: usize) -> (f64, f64) {
    let cfg = &engine.cfg;
    let mut rates = Vec::with_capacity(SAMPLES);
    for sample in 0..SAMPLES + 1 {
        // fresh caches per sample: prefill is untimed setup
        let mut caches: Vec<KvCache> = Vec::with_capacity(batch);
        let mut toks: Vec<u16> = Vec::with_capacity(batch);
        for s in 0..batch {
            let prompt: Vec<u16> = (0..PROMPT_LEN)
                .map(|i| ((i * 37 + s * 91 + sample * 13 + 7) % cfg.vocab) as u16)
                .collect();
            let mut c = KvCache::new(cfg, PROMPT_LEN + STEPS + 1);
            let logits = engine.prefill(&prompt, &mut c).unwrap();
            toks.push(sampling::argmax(&logits));
            caches.push(c);
        }
        let t = Timer::start();
        for _ in 0..STEPS {
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            let logits = engine.decode_batch(&toks, &mut refs).unwrap();
            for (s, tok) in toks.iter_mut().enumerate() {
                *tok = sampling::argmax(logits.row(s));
            }
        }
        let ms = t.ms();
        if sample == 0 {
            continue; // warmup
        }
        rates.push((batch * STEPS) as f64 / (ms / 1e3));
    }
    let med = stats::median(&rates);
    (med, 1e3 / med) // (tokens/s, ms per token)
}

fn main() {
    let cfg = ModelConfig::tiny_test();
    let weights = Weights::synthetic(&cfg, 7);
    let toks: Vec<u16> = (0..128u16).map(|i| (i * 37) % 256).collect();
    let fp = Engine::new(cfg.clone(), weights.clone(), EngineMode::Fp32, None).unwrap();
    let mut calib = BTreeMap::new();
    fp.forward(&toks, Some(&mut calib), None);

    let arc = Method::ArcQuant { fmt: Format::Nvfp4, max_s: Some(64) };
    let variants: Vec<(&str, EngineMode)> = vec![
        ("fp32", EngineMode::Fp32),
        ("arcquant", EngineMode::Quantized(arc.clone())),
        ("arcquant-packed", EngineMode::QuantizedPacked(arc)),
    ];

    println!("# decode throughput, prompt={PROMPT_LEN} steps={STEPS} (median of {SAMPLES})");
    let mut rows: Vec<Json> = Vec::new();
    let mut tok_s_by: BTreeMap<(String, usize), f64> = BTreeMap::new();
    for (name, mode) in variants {
        let engine =
            Engine::new(cfg.clone(), weights.clone(), mode, Some(&calib)).unwrap();
        for batch in [1usize, 4, 8] {
            let (tok_s, ms_per_step) = decode_tok_s(&engine, batch);

            // KV page accounting for this steady-state batch: every
            // sequence sits at prompt + STEPS tokens when the window ends.
            let mut pm = KvPageManager::new(4096, cfg.d, cfg.l);
            for s in 0..batch {
                pm.admit(s as u64, PROMPT_LEN + STEPS).unwrap();
            }
            println!(
                "BENCH decode_{name}_b{batch} tok_s={tok_s:.1} ms_per_tok={ms_per_step:.3} \
                 kv_pages={} kv_page_bytes={}",
                pm.used_pages(),
                pm.bytes_used()
            );
            tok_s_by.insert((name.to_string(), batch), tok_s);

            let mut row = Json::obj();
            row.set("variant", Json::Str(name.into()))
                .set("batch", Json::Num(batch as f64))
                .set("tokens_per_s", Json::Num(tok_s))
                .set("ms_per_token", Json::Num(ms_per_step))
                .set("kv_pages", Json::Num(pm.used_pages() as f64))
                .set("kv_page_bytes", Json::Num(pm.bytes_used() as f64))
                .set("weight_bytes", Json::Num(engine.weight_bytes() as f64));
            rows.push(row);
        }
    }

    for batch in [1usize, 4, 8] {
        let fp = tok_s_by[&("fp32".to_string(), batch)];
        let packed = tok_s_by[&("arcquant-packed".to_string(), batch)];
        println!("#   b{batch}: packed/fp32 decode ratio {:.2}x", packed / fp);
    }

    let mut out = Json::obj();
    out.set("bench", Json::Str("decode".into()))
        .set("model", Json::Str(cfg.name.clone()))
        .set("prompt_len", Json::Num(PROMPT_LEN as f64))
        .set("steps", Json::Num(STEPS as f64))
        .set("rows", Json::Arr(rows));
    let path = "BENCH_decode.json";
    match std::fs::write(path, out.dump()) {
        Ok(()) => println!("# wrote {path}"),
        Err(e) => eprintln!("# could not write {path}: {e}"),
    }
}
