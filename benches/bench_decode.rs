//! Decode-throughput bench: ArcPacked vs Fp32 (and the QDQ ArcQuant
//! reference) batched decode over per-sequence KV caches, at batch sizes
//! {1, 4, 8} — the serving-side counterpart of `bench_gemm_aug`'s
//! kernel-level comparison. Emits `BENCH_decode.json` with tokens/s per
//! (variant, batch) plus the KV page-manager accounting, so the decode
//! trajectory of the packed datapath is tracked across PRs.
//!
//! Also records a decode-site kernel comparison: the pre-v2 packed kernel
//! ([`matmul_nt_packed_ref`]) vs the v2 tiled/row kernels
//! ([`matmul_nt_packed`]) at the [B, K]·[M, K]ᵀ shapes a decode tick
//! issues per layer, B ∈ {1, 4, 8} — plus the SIMD dispatch series
//! (v2 forced scalar vs best detected path, `decode_site_simd`) and the
//! KV decode-on-access read cost (`kv_read`: `dequant_into` scalar vs
//! SIMD vs the raw f32 copy, the read `attention_over_cache` issues).
//! Emits stable `GATE key value` lines for `scripts/bench_gate.py`.
//!
//! Method: per sample, prefill `batch` fresh prompts (untimed), then time
//! `steps` consecutive `decode_batch` ticks and report
//! `batch · steps / elapsed`. Median over samples. Fixed work per timing
//! window (instead of the adaptive `Bencher`) because every decode tick
//! grows the caches — throughput at unbounded iteration counts would
//! measure ever-longer attention spans.
//!
//! Also emits the **KV-format series** (`kv_format_rows` /
//! `kv_capacity`): decode throughput with the K/V pages stored f32 vs
//! NVFP4/MXFP4 on the same packed engine, and the max sequences a fixed
//! page budget admits per format (the capacity lever `--kv-format`
//! exposes — see `docs/kv_cache.md`).
//!
//! `ARCQUANT_BENCH_SMOKE=1` shrinks every shape and skips the JSON
//! rewrite — CI uses it to catch kernel-routing panics cheaply.

use arcquant::baselines::Method;
use arcquant::coordinator::kvcache::KvPageManager;
use arcquant::formats::{Format, KvFormat, RowQuantizer};
use arcquant::model::{sampling, Engine, EngineMode, KvCache, ModelConfig, Weights};
use arcquant::tensor::simd::{self, SimdPath};
use arcquant::tensor::{matmul_nt_packed, matmul_nt_packed_ref, Mat};
use arcquant::util::bench::{smoke_mode, Bencher};
use arcquant::util::json::Json;
use arcquant::util::prop::gens::outlier_mat;
use arcquant::util::{stats, Prng, Timer};
use std::collections::BTreeMap;

struct Cfg {
    prompt_len: usize,
    steps: usize,
    samples: usize,
    batches: &'static [usize],
}

fn bench_cfg() -> Cfg {
    if smoke_mode() {
        Cfg { prompt_len: 4, steps: 2, samples: 1, batches: &[1, 2] }
    } else {
        Cfg { prompt_len: 16, steps: 16, samples: 5, batches: &[1, 4, 8] }
    }
}

fn decode_tok_s(engine: &Engine, batch: usize, bc: &Cfg, kv: KvFormat) -> (f64, f64) {
    let cfg = &engine.cfg;
    let mut rates = Vec::with_capacity(bc.samples);
    for sample in 0..bc.samples + 1 {
        // fresh caches per sample: prefill is untimed setup
        let mut caches: Vec<KvCache> = Vec::with_capacity(batch);
        let mut toks: Vec<u16> = Vec::with_capacity(batch);
        for s in 0..batch {
            let prompt: Vec<u16> = (0..bc.prompt_len)
                .map(|i| ((i * 37 + s * 91 + sample * 13 + 7) % cfg.vocab) as u16)
                .collect();
            let mut c = KvCache::with_format(cfg, bc.prompt_len + bc.steps + 1, kv);
            let logits = engine.prefill(&prompt, &mut c).unwrap();
            toks.push(sampling::argmax(&logits));
            caches.push(c);
        }
        let t = Timer::start();
        for _ in 0..bc.steps {
            let mut refs: Vec<&mut KvCache> = caches.iter_mut().collect();
            let logits = engine.decode_batch(&toks, &mut refs).unwrap();
            for (s, tok) in toks.iter_mut().enumerate() {
                *tok = sampling::argmax(logits.row(s));
            }
        }
        let ms = t.ms();
        if sample == 0 {
            continue; // warmup
        }
        rates.push((batch * bc.steps) as f64 / (ms / 1e3));
    }
    let med = stats::median(&rates);
    (med, 1e3 / med) // (tokens/s, ms per token)
}

/// Kernel v1-vs-v2 at the per-layer GEMM shape a decode tick issues:
/// [B, K] activations (already packed) against an [M, K] packed weight,
/// plus the SIMD dispatch series (v2 forced scalar vs best detected
/// path) on the same operands. Returns the (v2/v1, best/scalar) geomean
/// speedups over the batch sizes.
fn bench_decode_site_kernels(
    rows: &mut Vec<Json>,
    simd_rows: &mut Vec<Json>,
) -> (f64, f64) {
    let (k, m) = if smoke_mode() { (256usize, 32usize) } else { (2048usize, 512usize) };
    let batches: &[usize] = if smoke_mode() { &[1, 2] } else { &[1, 4, 8] };
    let b = if smoke_mode() { Bencher::smoke() } else { Bencher::quick() };
    let mut rng = Prng::new(9);
    let q = RowQuantizer::new(Format::Nvfp4);
    let mut w = Mat::zeros(m, k);
    w.fill_random_normal(&mut rng, 0.4);
    let qw = q.quantize(&w);
    let best_path = if simd::avx2_available() { "avx2" } else { "scalar" };
    let mut speedups: Vec<f64> = Vec::new();
    let mut simd_speedups: Vec<f64> = Vec::new();
    for &batch in batches {
        let x = outlier_mat(&mut rng, batch, k);
        let qx = q.quantize_rowwise(&x);
        let r_v1 = b.run(&format!("decode_site_kernel_v1_b{batch}"), || {
            matmul_nt_packed_ref(&qx, &qw)
        });
        let r_v2 = b.run(&format!("decode_site_kernel_v2_b{batch}"), || {
            matmul_nt_packed(&qx, &qw)
        });
        let speedup = r_v1.median_us / r_v2.median_us;
        speedups.push(speedup);
        println!(
            "#   decode-site kernel b{batch} (K={k}, M={m}): v1 {:.1}us v2 {:.1}us ({speedup:.2}x)",
            r_v1.median_us, r_v2.median_us
        );
        let mut row = Json::obj();
        row.set("batch", Json::Num(batch as f64))
            .set("k", Json::Num(k as f64))
            .set("m", Json::Num(m as f64))
            .set("v1_median_us", Json::Num(r_v1.median_us))
            .set("v2_median_us", Json::Num(r_v2.median_us))
            .set("speedup_v2_over_v1", Json::Num(speedup));
        rows.push(row);

        simd::set_path_override(Some(SimdPath::Scalar));
        let r_scalar = b.run(&format!("decode_site_simd_scalar_b{batch}"), || {
            matmul_nt_packed(&qx, &qw)
        });
        simd::set_path_override(Some(SimdPath::Avx2));
        let r_best = b.run(&format!("decode_site_simd_{best_path}_b{batch}"), || {
            matmul_nt_packed(&qx, &qw)
        });
        simd::set_path_override(None);
        let sp = r_scalar.median_us / r_best.median_us;
        simd_speedups.push(sp);
        println!(
            "#   decode-site simd b{batch}: scalar {:.1}us {best_path} {:.1}us ({sp:.2}x)",
            r_scalar.median_us, r_best.median_us
        );
        let mut sr = Json::obj();
        sr.set("batch", Json::Num(batch as f64))
            .set("k", Json::Num(k as f64))
            .set("m", Json::Num(m as f64))
            .set("scalar_median_us", Json::Num(r_scalar.median_us))
            .set("best_median_us", Json::Num(r_best.median_us))
            .set("best_path", Json::Str(best_path.into()))
            .set("speedup_best_over_scalar", Json::Num(sp));
        simd_rows.push(sr);
    }
    (stats::geomean(&speedups), stats::geomean(&simd_speedups))
}

/// KV decode-on-access read cost: `dequant_into` of a [T, d] NVFP4 K/V
/// matrix — the per-layer read `attention_over_cache` issues — forced
/// scalar vs the best detected path, with the raw f32 copy as the
/// zero-decode baseline. Returns the best-path speedup at the largest T.
fn bench_kv_read(rows: &mut Vec<Json>) -> f64 {
    let d = 128usize;
    let ts: &[usize] = if smoke_mode() { &[8] } else { &[48, 512] };
    let b = if smoke_mode() { Bencher::smoke() } else { Bencher::quick() };
    let best_path = if simd::avx2_available() { "avx2" } else { "scalar" };
    let mut rng = Prng::new(11);
    let q = RowQuantizer::new(Format::Nvfp4);
    let mut last_sp = 1.0;
    for &t in ts {
        let mut kmat = Mat::zeros(t, d);
        kmat.fill_random_normal(&mut rng, 0.8);
        let qk = q.quantize(&kmat);
        let mut out = vec![0f32; t * d];
        let r_copy = b.run(&format!("kv_read_f32_copy_t{t}"), || {
            out.copy_from_slice(&kmat.data);
            out[0]
        });
        simd::set_path_override(Some(SimdPath::Scalar));
        let r_scalar = b.run(&format!("kv_read_dequant_scalar_t{t}"), || {
            qk.dequant_into(&mut out);
            out[0]
        });
        simd::set_path_override(Some(SimdPath::Avx2));
        let r_best = b.run(&format!("kv_read_dequant_{best_path}_t{t}"), || {
            qk.dequant_into(&mut out);
            out[0]
        });
        simd::set_path_override(None);
        let sp = r_scalar.median_us / r_best.median_us;
        last_sp = sp;
        println!(
            "#   kv read t={t} d={d}: f32 copy {:.2}us, dequant scalar {:.2}us \
             ({:.2}x over copy), {best_path} {:.2}us ({:.2}x over copy, {sp:.2}x over scalar)",
            r_copy.median_us,
            r_scalar.median_us,
            r_scalar.median_us / r_copy.median_us,
            r_best.median_us,
            r_best.median_us / r_copy.median_us,
        );
        let mut row = Json::obj();
        row.set("t", Json::Num(t as f64))
            .set("d", Json::Num(d as f64))
            .set("kv_format", Json::Str("nvfp4".into()))
            .set("f32_copy_median_us", Json::Num(r_copy.median_us))
            .set("dequant_scalar_median_us", Json::Num(r_scalar.median_us))
            .set("dequant_best_median_us", Json::Num(r_best.median_us))
            .set("best_path", Json::Str(best_path.into()))
            .set("scalar_over_f32_copy", Json::Num(r_scalar.median_us / r_copy.median_us))
            .set("best_over_f32_copy", Json::Num(r_best.median_us / r_copy.median_us))
            .set("speedup_best_over_scalar", Json::Num(sp));
        rows.push(row);
    }
    last_sp
}

/// KV-format capacity series: max sequences a fixed page budget admits
/// per [`KvFormat`], under the executor's worst-case admission rule
/// (pure page accounting — exact, not timed). Returns the admitted
/// count per format; `main` turns these into the per-format GATE ratios.
fn bench_kv_capacity(
    d: usize,
    layers: usize,
    page_budget: usize,
    prompt_len: usize,
    max_new: usize,
    rows: &mut Vec<Json>,
) -> Vec<(KvFormat, usize)> {
    let worst = prompt_len + max_new;
    let mut admitted_by: Vec<(KvFormat, usize)> = Vec::new();
    for kv in KvFormat::ALL {
        let mut pm = KvPageManager::with_format(page_budget, d, layers, kv);
        let mut n = 0u64;
        // executor-style admission: free pages must cover the sequence's
        // own worst case before its prompt pages are reserved
        while pm.free_pages() >= pm.pages_for(worst) && pm.admit(n, prompt_len).is_ok()
        {
            pm.extend(n, max_new).unwrap();
            n += 1;
        }
        let admitted = n as usize;
        println!(
            "BENCH kv_capacity_{} page_budget={page_budget} worst_tokens={worst} \
             tokens_per_page={} pages_per_seq={} admitted_sequences={admitted}",
            kv.name(),
            pm.page_tokens,
            pm.pages_for(worst),
        );
        let mut row = Json::obj();
        row.set("kv_format", Json::Str(kv.name().into()))
            .set("page_budget", Json::Num(page_budget as f64))
            .set("worst_case_tokens", Json::Num(worst as f64))
            .set("tokens_per_page", Json::Num(pm.page_tokens as f64))
            .set("pages_per_seq", Json::Num(pm.pages_for(worst) as f64))
            .set("admitted_sequences", Json::Num(admitted as f64))
            .set("bytes_per_page", Json::Num(pm.bytes_per_page as f64));
        rows.push(row);
        admitted_by.push((kv, admitted));
    }
    admitted_by
}

fn main() {
    let bc = bench_cfg();
    let cfg = ModelConfig::tiny_test();
    let weights = Weights::synthetic(&cfg, 7);
    let toks: Vec<u16> = (0..128u16).map(|i| (i * 37) % 256).collect();
    let fp = Engine::new(cfg.clone(), weights.clone(), EngineMode::Fp32, None).unwrap();
    let mut calib = BTreeMap::new();
    fp.forward(&toks, Some(&mut calib), None);

    let arc = Method::ArcQuant { fmt: Format::Nvfp4, max_s: Some(64) };
    let variants: Vec<(&str, EngineMode)> = vec![
        ("fp32", EngineMode::Fp32),
        ("arcquant", EngineMode::Quantized(arc.clone())),
        ("arcquant-packed", EngineMode::QuantizedPacked(arc)),
    ];

    println!(
        "# decode throughput, prompt={} steps={} (median of {})",
        bc.prompt_len, bc.steps, bc.samples
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut tok_s_by: BTreeMap<(String, usize), f64> = BTreeMap::new();
    for (name, mode) in variants {
        let engine =
            Engine::new(cfg.clone(), weights.clone(), mode, Some(&calib)).unwrap();
        for &batch in bc.batches {
            let (tok_s, ms_per_step) = decode_tok_s(&engine, batch, &bc, KvFormat::Fp32);

            // KV page accounting for this steady-state batch: every
            // sequence sits at prompt + steps tokens when the window ends.
            let mut pm = KvPageManager::new(4096, cfg.d, cfg.l);
            for s in 0..batch {
                pm.admit(s as u64, bc.prompt_len + bc.steps).unwrap();
            }
            println!(
                "BENCH decode_{name}_b{batch} tok_s={tok_s:.1} ms_per_tok={ms_per_step:.3} \
                 kv_pages={} kv_page_bytes={}",
                pm.used_pages(),
                pm.bytes_used()
            );
            tok_s_by.insert((name.to_string(), batch), tok_s);

            let mut row = Json::obj();
            row.set("variant", Json::Str(name.into()))
                .set("batch", Json::Num(batch as f64))
                .set("tokens_per_s", Json::Num(tok_s))
                .set("ms_per_token", Json::Num(ms_per_step))
                .set("kv_pages", Json::Num(pm.used_pages() as f64))
                .set("kv_page_bytes", Json::Num(pm.bytes_used() as f64))
                .set("weight_bytes", Json::Num(engine.weight_bytes() as f64));
            rows.push(row);
        }
    }

    for &batch in bc.batches {
        let fp = tok_s_by[&("fp32".to_string(), batch)];
        let packed = tok_s_by[&("arcquant-packed".to_string(), batch)];
        println!("#   b{batch}: packed/fp32 decode ratio {:.2}x", packed / fp);
    }

    let mut kernel_rows: Vec<Json> = Vec::new();
    let mut simd_rows: Vec<Json> = Vec::new();
    let (site_geomean, simd_geomean) =
        bench_decode_site_kernels(&mut kernel_rows, &mut simd_rows);
    println!("# decode-site kernel geomean speedup v2/v1: {site_geomean:.2}x");
    println!("# decode-site simd geomean speedup best/scalar: {simd_geomean:.2}x");

    let mut kv_read_rows: Vec<Json> = Vec::new();
    let kv_read_sp = bench_kv_read(&mut kv_read_rows);

    // GATE lines: stable key/value pairs scripts/bench_gate.py floors in
    // CI (printed in smoke mode too).
    println!("GATE decode_site_geomean_v2_over_v1 {site_geomean:.4}");
    println!("GATE decode_site_simd_geomean_best_over_scalar {simd_geomean:.4}");
    println!("GATE decode_kv_read_speedup_best_over_scalar {kv_read_sp:.4}");

    // ---- KV-format series: same packed engine, K/V pages f32 vs 4-bit ----
    let kv_engine = Engine::new(
        cfg.clone(),
        weights.clone(),
        EngineMode::QuantizedPacked(Method::ArcQuant {
            fmt: Format::Nvfp4,
            max_s: Some(64),
        }),
        Some(&calib),
    )
    .unwrap();
    let kv_batch = if smoke_mode() { 2usize } else { 4 };
    let mut kv_rows: Vec<Json> = Vec::new();
    let mut kv_tok_s: BTreeMap<&'static str, f64> = BTreeMap::new();
    for kv in KvFormat::ALL {
        let (tok_s, ms_per_step) = decode_tok_s(&kv_engine, kv_batch, &bc, kv);
        println!(
            "BENCH kv_decode_{}_b{kv_batch} tok_s={tok_s:.1} ms_per_tok={ms_per_step:.3}",
            kv.name()
        );
        kv_tok_s.insert(kv.name(), tok_s);
        let mut row = Json::obj();
        row.set("kv_format", Json::Str(kv.name().into()))
            .set("variant", Json::Str("arcquant-packed".into()))
            .set("batch", Json::Num(kv_batch as f64))
            .set("tokens_per_s", Json::Num(tok_s))
            .set("ms_per_token", Json::Num(ms_per_step));
        kv_rows.push(row);
    }
    println!(
        "#   nvfp4-KV/fp32-KV decode throughput ratio {:.2}x",
        kv_tok_s["nvfp4"] / kv_tok_s["fp32"]
    );

    // capacity at a fixed page budget (exact accounting, not timed)
    let (kv_budget, kv_prompt, kv_new) =
        if smoke_mode() { (16usize, 24usize, 8usize) } else { (64, 96, 32) };
    let mut kv_cap_rows: Vec<Json> = Vec::new();
    let admitted_by =
        bench_kv_capacity(cfg.d, cfg.l, kv_budget, kv_prompt, kv_new, &mut kv_cap_rows);
    let admitted = |kv: KvFormat| -> f64 {
        admitted_by.iter().find(|(f, _)| *f == kv).map(|&(_, n)| n as f64).unwrap()
    };
    let cap_ratio = admitted(KvFormat::Nvfp4) / admitted(KvFormat::Fp32);
    println!("#   nvfp4-KV/fp32-KV admitted-sequence ratio {cap_ratio:.2}x");
    // per-format capacity GATE rows: deterministic page accounting, so
    // the gate floors catch a codec whose page geometry regresses (a
    // razer/fouroversix page must stay as dense as an nvfp4 page)
    for kv in [KvFormat::Mxfp4, KvFormat::Razer4, KvFormat::FourOverSix] {
        println!(
            "GATE decode_kv_capacity_{}_over_fp32 {:.4}",
            kv.name(),
            admitted(kv) / admitted(KvFormat::Fp32)
        );
    }

    if smoke_mode() {
        println!("# smoke mode: BENCH_decode.json not rewritten");
        return;
    }
    // Keep the top-level schema identical to the committed baseline so
    // regeneration diffs show perf deltas, not schema churn.
    let mut prov = Json::obj();
    prov.set(
        "source",
        Json::Str("cargo bench --bench bench_decode (in-tree harness)".into()),
    )
    .set("threads", Json::Num(arcquant::util::pool::num_threads() as f64));
    let mut out = Json::obj();
    out.set("bench", Json::Str("decode".into()))
        .set("provenance", prov)
        .set("model", Json::Str(cfg.name.clone()))
        .set("prompt_len", Json::Num(bc.prompt_len as f64))
        .set("steps", Json::Num(bc.steps as f64))
        .set("rows", Json::Arr(rows))
        .set("decode_site_kernel", Json::Arr(kernel_rows))
        .set("decode_site_kernel_geomean_speedup", Json::Num(site_geomean))
        .set("decode_site_simd", Json::Arr(simd_rows))
        .set("decode_site_simd_geomean_speedup", Json::Num(simd_geomean))
        .set("kv_read", Json::Arr(kv_read_rows))
        .set("kv_format_rows", Json::Arr(kv_rows))
        .set("kv_capacity", Json::Arr(kv_cap_rows))
        .set("kv_capacity_ratio_nvfp4_over_fp32", Json::Num(cap_ratio))
        .set(
            "kv_capacity_ratio_razer_over_fp32",
            Json::Num(admitted(KvFormat::Razer4) / admitted(KvFormat::Fp32)),
        )
        .set(
            "kv_capacity_ratio_fouroversix_over_fp32",
            Json::Num(admitted(KvFormat::FourOverSix) / admitted(KvFormat::Fp32)),
        );
    let path = "BENCH_decode.json";
    match std::fs::write(path, out.dump()) {
        Ok(()) => println!("# wrote {path}"),
        Err(e) => {
            // a failed trajectory rewrite must fail the run, or the
            // runner would report success over stale numbers
            eprintln!("# could not write {path}: {e}");
            std::process::exit(1);
        }
    }
}
