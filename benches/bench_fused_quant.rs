//! Fused-quantization hot-path bench: reorder + primary + residual quant
//! (the Rust mirror of the L1 kernel), across S — the online cost
//! ARCQuant adds per request.

use arcquant::formats::Format;
use arcquant::quant::{ArcQuantizer, LayerPlan, Permutation};
use arcquant::tensor::Mat;
use arcquant::util::bench::Bencher;
use arcquant::util::Prng;

fn main() {
    let b = Bencher::default();
    let (n, k) = (64usize, 1024usize);
    let mut rng = Prng::new(0);
    let x = Mat::from_fn(n, k, |_, c| {
        let v = rng.normal();
        if c % 31 == 2 { v * 40.0 } else { v }
    });
    for s in [0usize, 64, 256, 512] {
        let plan = LayerPlan {
            perm: Permutation::sort_desc(&x.col_absmax()),
            s,
            fmt: Format::Nvfp4,
        };
        let q = ArcQuantizer::new(plan);
        b.run(&format!("fused_quant_n{n}_k{k}_s{s}"), || {
            q.quantize_activations(&x)
        });
    }
    // block quantization alone (the primary stage) for the breakdown
    let rq = arcquant::formats::RowQuantizer::new(Format::Nvfp4);
    b.run("primary_qdq_only", || rq.qdq_mat(&x));
}
