//! HTTP serving bench: a real in-process [`HttpServer`] over the tiny
//! synthetic model, driven across loopback TCP by the closed-loop
//! loadgen at connection counts {1, 4, 16} — emits `BENCH_http.json`
//! with end-to-end tokens/s and latency percentiles per connection
//! count, so the networked serving path's trajectory is tracked across
//! PRs alongside the kernel and decode series.
//!
//! The closed loop means concurrency equals the connection count: the
//! throughput climb from 1 → 4 → 16 connections is exactly the
//! continuous-batching win (shared decode ticks), since a single
//! connection can never batch with itself.
//!
//! A final open-loop pass offers the same fixed Poisson arrival rate to
//! a 1-replica and a 3-replica server and reports goodput under an SLO
//! for each — the r3/r1 goodput ratio (`GATE http_goodput_open_loop`)
//! is the replica-tier scaling headline (`replica_goodput_speedup`).
//!
//! `ARCQUANT_BENCH_SMOKE=1` shrinks the series and skips the JSON
//! rewrite — CI uses it to exercise the full socket path (server boot,
//! keep-alive clients, chunked streaming, drain) every push.

use arcquant::baselines::Method;
use arcquant::coordinator::{
    run_loadgen, run_open_loop, HttpServeConfig, HttpServer, LoadgenConfig,
    OpenLoopConfig, Variant,
};
use arcquant::formats::{Format, KvFormat};
use arcquant::model::{tiny_test_fixture, Engine, EngineMode};
use arcquant::util::json::Json;
use std::collections::BTreeMap;

struct Cfg {
    connections: &'static [usize],
    requests_per_conn: usize,
    prompt_len: usize,
    max_new: usize,
}

fn bench_cfg() -> Cfg {
    if arcquant::util::bench::smoke_mode() {
        Cfg {
            connections: &[1, 2],
            requests_per_conn: 2,
            prompt_len: 8,
            max_new: 4,
        }
    } else {
        Cfg {
            connections: &[1, 4, 16],
            requests_per_conn: 8,
            prompt_len: 16,
            max_new: 16,
        }
    }
}

fn engines() -> Vec<(Variant, Engine)> {
    let (cfg, weights, calib) = tiny_test_fixture(7, 128);
    let fp =
        Engine::new(cfg.clone(), weights.clone(), EngineMode::Fp32, None).unwrap();
    let packed = Engine::new(
        cfg,
        weights,
        EngineMode::QuantizedPacked(Method::ArcQuant {
            fmt: Format::Nvfp4,
            max_s: Some(64),
        }),
        Some(&calib),
    )
    .unwrap();
    vec![(Variant::ArcPacked, packed), (Variant::Fp32, fp)]
}

fn main() {
    let bc = bench_cfg();
    let smoke = arcquant::util::bench::smoke_mode();
    let server = HttpServer::start(
        HttpServeConfig {
            max_decode_batch: 16,
            kv_pages: 512,
            kv_format: KvFormat::Nvfp4,
            queue_cap: 128,
            ..Default::default()
        },
        "127.0.0.1:0",
        engines(),
    )
    .expect("bench server");
    let addr = server.addr().to_string();
    println!(
        "# http serving bench at {addr}: closed loop, {} requests/conn, \
         prompt={} max_new={}, nvfp4 KV pages",
        bc.requests_per_conn, bc.prompt_len, bc.max_new
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut tok_s_by: BTreeMap<usize, f64> = BTreeMap::new();
    for &conns in bc.connections {
        let cfg = LoadgenConfig {
            addr: addr.clone(),
            connections: conns,
            requests_per_conn: bc.requests_per_conn,
            prompt_len: bc.prompt_len,
            max_new_tokens: bc.max_new,
            variant: Some(Variant::ArcPacked),
            vocab: 256,
            stream: false,
            seed: 0,
            shared_prefix_len: 0,
            // measured rows take one attempt each — retries would fold
            // backoff sleeps into the latency percentiles
            no_retry: true,
        };
        // untimed warmup pass at the smallest shape, then the measured run
        if conns == bc.connections[0] {
            let _ = run_loadgen(&LoadgenConfig {
                requests_per_conn: 1,
                ..cfg.clone()
            });
        }
        let r = run_loadgen(&cfg).expect("loadgen");
        assert_eq!(
            r.errors, 0,
            "bench traffic must be error-free: {:?}",
            r.by_status
        );
        println!(
            "BENCH http_c{conns} tok_s={:.1} req_s={:.2} p50_ms={:.1} \
             p90_ms={:.1} p99_ms={:.1}",
            r.tok_s, r.req_s, r.p50_ms, r.p90_ms, r.p99_ms
        );
        tok_s_by.insert(conns, r.tok_s);
        let mut row = Json::obj();
        row.set("connections", Json::Num(conns as f64))
            .set("requests", Json::Num(r.requests as f64))
            .set("variant", Json::Str("arcquant-packed".into()))
            .set("tokens_per_s", Json::Num(r.tok_s))
            .set("requests_per_s", Json::Num(r.req_s))
            .set("p50_ms", Json::Num(r.p50_ms))
            .set("p90_ms", Json::Num(r.p90_ms))
            .set("p99_ms", Json::Num(r.p99_ms))
            .set("mean_ms", Json::Num(r.mean_ms));
        rows.push(row);
    }

    // one streaming pass: exercises the chunked path end to end
    let stream_r = run_loadgen(&LoadgenConfig {
        addr: addr.clone(),
        connections: 2,
        requests_per_conn: bc.requests_per_conn.min(4),
        prompt_len: bc.prompt_len,
        max_new_tokens: bc.max_new,
        variant: Some(Variant::ArcPacked),
        vocab: 256,
        stream: true,
        seed: 1,
        shared_prefix_len: 0,
        no_retry: true,
    })
    .expect("streaming loadgen");
    assert_eq!(stream_r.errors, 0, "streaming traffic must be error-free");
    println!(
        "BENCH http_stream_c2 tok_s={:.1} p99_ms={:.1}",
        stream_r.tok_s, stream_r.p99_ms
    );

    // shared-prefix pass: every request leads with the same 214-token
    // system prompt (= two full nvfp4 pages at the tiny-test shape), so
    // the content-addressed prefix cache serves the bulk of each prompt.
    // Streaming is on because TTFT is the headline of this scenario.
    let prefix_cfg = |addr: &str| LoadgenConfig {
        addr: addr.to_string(),
        connections: if smoke { 2 } else { 4 },
        requests_per_conn: bc.requests_per_conn,
        prompt_len: bc.prompt_len,
        max_new_tokens: bc.max_new,
        variant: Some(Variant::ArcPacked),
        vocab: 256,
        stream: true,
        seed: 2,
        shared_prefix_len: 214,
        no_retry: true,
    };
    let prefix_on = run_loadgen(&prefix_cfg(&addr)).expect("shared-prefix loadgen");
    assert_eq!(prefix_on.errors, 0, "shared-prefix traffic must be error-free");
    server.shutdown();

    // identical workload against a sharing-off server — the baseline the
    // reuse win is measured against (outputs are bit-identical; only
    // pages and prefill work differ)
    let off_server = HttpServer::start(
        HttpServeConfig {
            max_decode_batch: 16,
            kv_pages: 512,
            kv_format: KvFormat::Nvfp4,
            queue_cap: 128,
            share_prefix: false,
            ..Default::default()
        },
        "127.0.0.1:0",
        engines(),
    )
    .expect("bench server (sharing off)");
    let prefix_off = run_loadgen(&prefix_cfg(&off_server.addr().to_string()))
        .expect("shared-prefix loadgen (sharing off)");
    assert_eq!(prefix_off.errors, 0, "sharing-off traffic must be error-free");
    off_server.shutdown();

    // fault-recovery pass: the server's first decode tick panics
    // (injected), the supervised scheduler fails the in-flight request
    // (500), rebuilds, and the loadgen retry path resubmits — the
    // recovery metric is wall time for the whole ride-through, which the
    // smoke gate caps (scripts/bench_gate.py).
    let fault_server = HttpServer::start(
        HttpServeConfig {
            max_decode_batch: 16,
            kv_pages: 512,
            kv_format: KvFormat::Nvfp4,
            queue_cap: 128,
            faults: arcquant::util::fault::Faults::parse("tick_decode:1:panic")
                .expect("fault spec"),
            ..Default::default()
        },
        "127.0.0.1:0",
        engines(),
    )
    .expect("bench server (fault injection)");
    let fr = run_loadgen(&LoadgenConfig {
        addr: fault_server.addr().to_string(),
        connections: 1,
        requests_per_conn: 2,
        prompt_len: bc.prompt_len,
        max_new_tokens: bc.max_new,
        variant: Some(Variant::ArcPacked),
        vocab: 256,
        stream: false,
        seed: 3,
        shared_prefix_len: 0,
        no_retry: false,
    })
    .expect("fault-recovery loadgen");
    fault_server.shutdown();
    assert_eq!(
        fr.ok, fr.requests,
        "retries must ride through the injected panic: {:?}",
        fr.by_status
    );
    assert!(
        fr.retries >= 1,
        "the injected tick panic should have forced at least one retry"
    );
    println!(
        "BENCH http_fault_recovery ok={} retries={} wall_ms={:.1}",
        fr.ok, fr.retries, fr.wall_ms
    );
    println!("GATE http_recovery_ms {:.1}", fr.wall_ms);

    // open-loop replica-scaling pass: the same fixed Poisson offered
    // load against a 1-replica and a 3-replica server (identical
    // per-replica budgets), goodput = completions within the SLO per
    // second. The r3/r1 goodput ratio is the sharding win; the smoke
    // gate floors it (scripts/bench_gate.py, GATE
    // http_goodput_open_loop) so a tier regression that collapses
    // multi-replica serving fails CI even on small runners.
    let (ol_requests, ol_rate, ol_slo_ms) = if smoke {
        (16usize, 48.0, 500.0)
    } else {
        (96usize, 64.0, 500.0)
    };
    let open_loop_pass = |replicas: usize| {
        let srv = HttpServer::start(
            HttpServeConfig {
                replicas,
                max_decode_batch: 16,
                kv_pages: 512,
                pages_per_replica: 512,
                kv_format: KvFormat::Nvfp4,
                queue_cap: 128,
                ..Default::default()
            },
            "127.0.0.1:0",
            engines(),
        )
        .expect("bench server (open loop)");
        let r = run_open_loop(&OpenLoopConfig {
            addr: srv.addr().to_string(),
            requests: ol_requests,
            rate: ol_rate,
            slo_ms: ol_slo_ms,
            prompt_len: bc.prompt_len,
            max_new_tokens: bc.max_new,
            variant: Some(Variant::ArcPacked),
            vocab: 256,
            stream: false,
            seed: 4,
            shared_prefix_len: 0,
        })
        .expect("open-loop loadgen");
        srv.shutdown();
        // open loop has no retries, but the queue cap exceeds the total
        // request count, so every request must land
        assert_eq!(
            r.errors, 0,
            "{replicas}-replica open-loop traffic must be error-free: {:?}",
            r.by_status
        );
        r
    };
    let ol_r1 = open_loop_pass(1);
    let ol_r3 = open_loop_pass(3);
    println!(
        "BENCH http_openloop_r1 goodput_rps={:.2} offered_rps={:.2} \
         within_slo={} p50_ms={:.1} p99_ms={:.1}",
        ol_r1.goodput_rps,
        ol_r1.offered_rps,
        ol_r1.ok_within_slo,
        ol_r1.p50_ms,
        ol_r1.p99_ms
    );
    println!(
        "BENCH http_openloop_r3 goodput_rps={:.2} offered_rps={:.2} \
         within_slo={} p50_ms={:.1} p99_ms={:.1}",
        ol_r3.goodput_rps,
        ol_r3.offered_rps,
        ol_r3.ok_within_slo,
        ol_r3.p50_ms,
        ol_r3.p99_ms
    );
    let goodput_ratio = if ol_r1.goodput_rps > 0.0 {
        ol_r3.goodput_rps / ol_r1.goodput_rps
    } else {
        1.0
    };
    // the smoke gate floors this (BENCH_GATE_GOODPUT_FLOOR, default 0.7)
    println!("GATE http_goodput_open_loop {goodput_ratio:.3}");

    println!(
        "BENCH http_prefix_on tok_s={:.1} ttft_p50_ms={:.2} ttft_p99_ms={:.2} \
         hit_rate={:.3} pages_saved={}",
        prefix_on.tok_s,
        prefix_on.ttft_p50_ms,
        prefix_on.ttft_p99_ms,
        prefix_on.prefix_hit_rate,
        prefix_on.pages_saved
    );
    println!(
        "BENCH http_prefix_off tok_s={:.1} ttft_p50_ms={:.2} ttft_p99_ms={:.2}",
        prefix_off.tok_s, prefix_off.ttft_p50_ms, prefix_off.ttft_p99_ms
    );
    let ttft_speedup = if prefix_on.ttft_p50_ms > 0.0 {
        prefix_off.ttft_p50_ms / prefix_on.ttft_p50_ms
    } else {
        1.0
    };
    // the smoke gate floors this at 0.5 (scripts/bench_gate.py)
    println!("GATE http_prefix_hit_rate {:.3}", prefix_on.prefix_hit_rate);
    println!(
        "#   shared-prefix TTFT p50 {:.2}ms -> {:.2}ms ({ttft_speedup:.2}x, \
         {} pages saved)",
        prefix_off.ttft_p50_ms, prefix_on.ttft_p50_ms, prefix_on.pages_saved
    );

    let lo = bc.connections[0];
    let hi = bc.connections[bc.connections.len() - 1];
    println!(
        "#   {hi}-conn/{lo}-conn throughput ratio {:.2}x (continuous batching)",
        tok_s_by[&hi] / tok_s_by[&lo]
    );

    if smoke {
        println!("# smoke mode: BENCH_http.json not rewritten");
        return;
    }
    let mut prov = Json::obj();
    prov.set(
        "source",
        Json::Str("cargo bench --bench bench_http (in-tree harness)".into()),
    )
    .set(
        "threads",
        Json::Num(arcquant::util::pool::num_threads() as f64),
    );
    let mut stream_row = Json::obj();
    stream_row
        .set("connections", Json::Num(2.0))
        .set("tokens_per_s", Json::Num(stream_r.tok_s))
        .set("p99_ms", Json::Num(stream_r.p99_ms));
    let prefix_row = |r: &arcquant::coordinator::LoadgenReport| {
        let mut row = Json::obj();
        row.set("tokens_per_s", Json::Num(r.tok_s))
            .set("ttft_p50_ms", Json::Num(r.ttft_p50_ms))
            .set("ttft_p99_ms", Json::Num(r.ttft_p99_ms))
            .set("prefix_hit_rate", Json::Num(r.prefix_hit_rate))
            .set("pages_saved", Json::Num(r.pages_saved as f64));
        row
    };
    let mut prefix_reuse = Json::obj();
    prefix_reuse
        .set("shared_prefix_len", Json::Num(214.0))
        .set("connections", Json::Num(4.0))
        .set("sharing_on", prefix_row(&prefix_on))
        .set("sharing_off", prefix_row(&prefix_off));
    let ol_row = |replicas: usize, r: &arcquant::coordinator::OpenLoopReport| {
        let mut row = Json::obj();
        row.set("replicas", Json::Num(replicas as f64))
            .set("requests", Json::Num(r.requests as f64))
            .set("offered_rps", Json::Num(r.offered_rps))
            .set("goodput_rps", Json::Num(r.goodput_rps))
            .set("ok_within_slo", Json::Num(r.ok_within_slo as f64))
            .set("p50_ms", Json::Num(r.p50_ms))
            .set("p99_ms", Json::Num(r.p99_ms));
        row
    };
    let mut replica_scaling = Json::obj();
    replica_scaling
        .set("rate_rps", Json::Num(ol_rate))
        .set("slo_ms", Json::Num(ol_slo_ms))
        .set("rows", Json::Arr(vec![ol_row(1, &ol_r1), ol_row(3, &ol_r3)]));
    let mut out = Json::obj();
    out.set("bench", Json::Str("http".into()))
        .set("provenance", prov)
        .set("model", Json::Str("tiny-test".into()))
        .set("kv_format", Json::Str("nvfp4".into()))
        .set("prompt_len", Json::Num(bc.prompt_len as f64))
        .set("max_new_tokens", Json::Num(bc.max_new as f64))
        .set("requests_per_conn", Json::Num(bc.requests_per_conn as f64))
        .set("rows", Json::Arr(rows))
        .set("streaming", stream_row)
        .set("prefix_reuse", prefix_reuse)
        .set("replica_scaling", replica_scaling)
        // headline scalars for the trajectory gate
        .set("prefix_hit_rate", Json::Num(prefix_on.prefix_hit_rate))
        .set("prefix_ttft_speedup", Json::Num(ttft_speedup))
        .set("replica_goodput_speedup", Json::Num(goodput_ratio))
        // client-observed ride-through time of one injected tick panic
        .set("fault_recovery_ms", Json::Num(fr.wall_ms));
    let path = "BENCH_http.json";
    match std::fs::write(path, out.dump()) {
        Ok(()) => println!("# wrote {path}"),
        Err(e) => {
            // a failed trajectory rewrite must fail the run, or the
            // runner would report success over stale numbers
            eprintln!("# could not write {path}: {e}");
            std::process::exit(1);
        }
    }
}
