//! HTTP serving bench: a real in-process [`HttpServer`] over the tiny
//! synthetic model, driven across loopback TCP by the closed-loop
//! loadgen at connection counts {1, 4, 16} — emits `BENCH_http.json`
//! with end-to-end tokens/s and latency percentiles per connection
//! count, so the networked serving path's trajectory is tracked across
//! PRs alongside the kernel and decode series.
//!
//! The closed loop means concurrency equals the connection count: the
//! throughput climb from 1 → 4 → 16 connections is exactly the
//! continuous-batching win (shared decode ticks), since a single
//! connection can never batch with itself.
//!
//! `ARCQUANT_BENCH_SMOKE=1` shrinks the series and skips the JSON
//! rewrite — CI uses it to exercise the full socket path (server boot,
//! keep-alive clients, chunked streaming, drain) every push.

use arcquant::baselines::Method;
use arcquant::coordinator::{
    run_loadgen, HttpServeConfig, HttpServer, LoadgenConfig, Variant,
};
use arcquant::formats::{Format, KvFormat};
use arcquant::model::{tiny_test_fixture, Engine, EngineMode};
use arcquant::util::json::Json;
use std::collections::BTreeMap;

struct Cfg {
    connections: &'static [usize],
    requests_per_conn: usize,
    prompt_len: usize,
    max_new: usize,
}

fn bench_cfg() -> Cfg {
    if arcquant::util::bench::smoke_mode() {
        Cfg {
            connections: &[1, 2],
            requests_per_conn: 2,
            prompt_len: 8,
            max_new: 4,
        }
    } else {
        Cfg {
            connections: &[1, 4, 16],
            requests_per_conn: 8,
            prompt_len: 16,
            max_new: 16,
        }
    }
}

fn engines() -> Vec<(Variant, Engine)> {
    let (cfg, weights, calib) = tiny_test_fixture(7, 128);
    let fp =
        Engine::new(cfg.clone(), weights.clone(), EngineMode::Fp32, None).unwrap();
    let packed = Engine::new(
        cfg,
        weights,
        EngineMode::QuantizedPacked(Method::ArcQuant {
            fmt: Format::Nvfp4,
            max_s: Some(64),
        }),
        Some(&calib),
    )
    .unwrap();
    vec![(Variant::ArcPacked, packed), (Variant::Fp32, fp)]
}

fn main() {
    let bc = bench_cfg();
    let smoke = arcquant::util::bench::smoke_mode();
    let server = HttpServer::start(
        HttpServeConfig {
            max_decode_batch: 16,
            kv_pages: 512,
            kv_format: KvFormat::Nvfp4,
            queue_cap: 128,
            ..Default::default()
        },
        "127.0.0.1:0",
        engines(),
    )
    .expect("bench server");
    let addr = server.addr().to_string();
    println!(
        "# http serving bench at {addr}: closed loop, {} requests/conn, \
         prompt={} max_new={}, nvfp4 KV pages",
        bc.requests_per_conn, bc.prompt_len, bc.max_new
    );

    let mut rows: Vec<Json> = Vec::new();
    let mut tok_s_by: BTreeMap<usize, f64> = BTreeMap::new();
    for &conns in bc.connections {
        let cfg = LoadgenConfig {
            addr: addr.clone(),
            connections: conns,
            requests_per_conn: bc.requests_per_conn,
            prompt_len: bc.prompt_len,
            max_new_tokens: bc.max_new,
            variant: Some(Variant::ArcPacked),
            vocab: 256,
            stream: false,
            seed: 0,
        };
        // untimed warmup pass at the smallest shape, then the measured run
        if conns == bc.connections[0] {
            let _ = run_loadgen(&LoadgenConfig {
                requests_per_conn: 1,
                ..cfg.clone()
            });
        }
        let r = run_loadgen(&cfg).expect("loadgen");
        assert_eq!(
            r.errors, 0,
            "bench traffic must be error-free: {:?}",
            r.by_status
        );
        println!(
            "BENCH http_c{conns} tok_s={:.1} req_s={:.2} p50_ms={:.1} \
             p90_ms={:.1} p99_ms={:.1}",
            r.tok_s, r.req_s, r.p50_ms, r.p90_ms, r.p99_ms
        );
        tok_s_by.insert(conns, r.tok_s);
        let mut row = Json::obj();
        row.set("connections", Json::Num(conns as f64))
            .set("requests", Json::Num(r.requests as f64))
            .set("variant", Json::Str("arcquant-packed".into()))
            .set("tokens_per_s", Json::Num(r.tok_s))
            .set("requests_per_s", Json::Num(r.req_s))
            .set("p50_ms", Json::Num(r.p50_ms))
            .set("p90_ms", Json::Num(r.p90_ms))
            .set("p99_ms", Json::Num(r.p99_ms))
            .set("mean_ms", Json::Num(r.mean_ms));
        rows.push(row);
    }

    // one streaming pass: exercises the chunked path end to end
    let stream_r = run_loadgen(&LoadgenConfig {
        addr: addr.clone(),
        connections: 2,
        requests_per_conn: bc.requests_per_conn.min(4),
        prompt_len: bc.prompt_len,
        max_new_tokens: bc.max_new,
        variant: Some(Variant::ArcPacked),
        vocab: 256,
        stream: true,
        seed: 1,
    })
    .expect("streaming loadgen");
    assert_eq!(stream_r.errors, 0, "streaming traffic must be error-free");
    println!(
        "BENCH http_stream_c2 tok_s={:.1} p99_ms={:.1}",
        stream_r.tok_s, stream_r.p99_ms
    );

    server.shutdown();

    let lo = bc.connections[0];
    let hi = bc.connections[bc.connections.len() - 1];
    println!(
        "#   {hi}-conn/{lo}-conn throughput ratio {:.2}x (continuous batching)",
        tok_s_by[&hi] / tok_s_by[&lo]
    );

    if smoke {
        println!("# smoke mode: BENCH_http.json not rewritten");
        return;
    }
    let mut prov = Json::obj();
    prov.set(
        "source",
        Json::Str("cargo bench --bench bench_http (in-tree harness)".into()),
    )
    .set(
        "threads",
        Json::Num(arcquant::util::pool::num_threads() as f64),
    );
    let mut stream_row = Json::obj();
    stream_row
        .set("connections", Json::Num(2.0))
        .set("tokens_per_s", Json::Num(stream_r.tok_s))
        .set("p99_ms", Json::Num(stream_r.p99_ms));
    let mut out = Json::obj();
    out.set("bench", Json::Str("http".into()))
        .set("provenance", prov)
        .set("model", Json::Str("tiny-test".into()))
        .set("kv_format", Json::Str("nvfp4".into()))
        .set("prompt_len", Json::Num(bc.prompt_len as f64))
        .set("max_new_tokens", Json::Num(bc.max_new as f64))
        .set("requests_per_conn", Json::Num(bc.requests_per_conn as f64))
        .set("rows", Json::Arr(rows))
        .set("streaming", stream_row);
    let path = "BENCH_http.json";
    match std::fs::write(path, out.dump()) {
        Ok(()) => println!("# wrote {path}"),
        Err(e) => {
            // a failed trajectory rewrite must fail the run, or the
            // runner would report success over stale numbers
            eprintln!("# could not write {path}: {e}");
            std::process::exit(1);
        }
    }
}
