//! Per-layer quantization-method bench (Tables 1/2 cost side): online
//! forward latency of one linear under every method, identical input.

use arcquant::baselines::{LayerCalib, Method, PreparedLinear};
use arcquant::formats::Format;
use arcquant::tensor::Mat;
use arcquant::util::bench::Bencher;
use arcquant::util::Prng;

fn main() {
    let b = Bencher::default();
    let (n, k, m) = (64usize, 1024usize, 1024usize);
    let mut rng = Prng::new(0);
    let x = Mat::from_fn(n, k, |_, c| {
        let v = rng.normal();
        if c % 29 == 3 { v * 50.0 } else { v }
    });
    let mut w = Mat::zeros(m, k);
    w.fill_random_normal(&mut rng, 0.3);
    let calib = LayerCalib::from_activations(&x);

    let methods: Vec<(&str, Method)> = vec![
        ("fp16", Method::Fp16),
        ("nvfp4_rtn", Method::Rtn { fmt: Format::Nvfp4 }),
        ("w4a8_rtn", Method::W4A8Rtn),
        ("smooth", Method::Smooth { fmt: Format::Nvfp4, alpha: 0.5 }),
        ("quarot", Method::QuaRot { fmt: Format::Nvfp4, seed: 0 }),
        ("flatquant", Method::FlatQuant { fmt: Format::Nvfp4 }),
        ("atom", Method::Atom { outlier_channels: 128 }),
        ("arcquant", Method::ArcQuant { fmt: Format::Nvfp4, max_s: Some(512) }),
    ];
    for (name, method) in methods {
        let lin = PreparedLinear::prepare(&method, &w, &calib);
        b.run(&format!("linear_fwd_{name}_{n}x{k}x{m}"), || lin.forward(&x));
    }
}
