//! Table 8 / Figure 6 bench: Rust-native engine prefill latency per
//! method (measured, this host) + modeled paper-scale GPU estimates.

use arcquant::baselines::Method;
use arcquant::costmodel::{prefill_estimate, GemmPath, Gpu};
use arcquant::formats::Format;
use arcquant::model::{Engine, EngineMode, ModelConfig, Weights};
use arcquant::util::bench::Bencher;
use std::collections::BTreeMap;

fn main() {
    let cfg = ModelConfig::tiny_test();
    let weights = Weights::synthetic(&cfg, 7);
    let toks: Vec<u16> = (0..128u16).map(|i| (i * 37) % 256).collect();

    // calibration once
    let fp = Engine::new(cfg.clone(), weights.clone(), EngineMode::Fp32, None).unwrap();
    let mut calib = BTreeMap::new();
    fp.forward(&toks, Some(&mut calib), None);

    let b = Bencher::quick();
    let methods: Vec<(&str, EngineMode)> = vec![
        ("fp32", EngineMode::Fp32),
        ("nvfp4_rtn", EngineMode::Quantized(Method::Rtn { fmt: Format::Nvfp4 })),
        (
            "arcquant",
            EngineMode::Quantized(Method::ArcQuant { fmt: Format::Nvfp4, max_s: Some(128) }),
        ),
        ("w4a8", EngineMode::Quantized(Method::W4A8Rtn)),
        ("atom", EngineMode::Quantized(Method::Atom { outlier_channels: 128 })),
    ];
    for (name, mode) in methods {
        let e = Engine::new(cfg.clone(), weights.clone(), mode, Some(&calib)).unwrap();
        b.run(&format!("prefill_host_{name}_t128"), || {
            e.forward(&toks, None, None)
        });
    }

    println!("# modeled paper-scale prefill (Table 8 rows):");
    for (gpu, model, bsz, len) in [
        (Gpu::Rtx5090, "qwen7b-sim", 4usize, 2048usize),
        (Gpu::RtxPro6000, "qwen7b-sim", 32, 2048),
        (Gpu::RtxPro6000, "qwen32b-sim", 8, 2048),
    ] {
        let fp = prefill_estimate(gpu, model, GemmPath::Fp16, bsz, len, 0);
        let arc = prefill_estimate(gpu, model, GemmPath::Nvfp4Aug { s: 256 }, bsz, len, 256);
        println!(
            "MODEL prefill {} {model} {bsz}/{len}: fp16={:.0}ms arc={:.0}ms speedup={:.2}x mem {:.1}->{:.1}GB",
            gpu.spec().name, fp.latency_ms, arc.latency_ms, fp.latency_ms / arc.latency_ms,
            fp.memory_gb, arc.memory_gb
        );
    }
}
