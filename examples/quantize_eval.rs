//! Full PTQ pipeline on a trained model: calibrate → derive plans →
//! quantize → evaluate PPL + zero-shot tasks, for every method in the
//! paper's comparison set (Tables 1 & 2 workflow on one model).
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example quantize_eval [model] [--quick]

use arcquant::baselines::Method;
use arcquant::formats::Format;
use arcquant::report::{Ctx, EvalBudget};
use arcquant::util::Timer;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "qwen7b-sim".to_string());
    let quick = args.iter().any(|a| a == "--quick");
    let budget = if quick {
        EvalBudget::quick()
    } else {
        EvalBudget::default()
    };
    let ctx = Ctx::new("artifacts", budget);

    // Rust-side calibration (the paper's offline phase), then evaluate
    // the full method sweep with the shipped Python calibration so the
    // two pipelines cross-check.
    let (cfg, w) = match ctx.model(&model) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("cannot load model ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    let stream = ctx
        .corpus(arcquant::report::ctx::model_domain(&model))
        .unwrap();
    let t = Timer::start();
    let calib = arcquant::calib::run_calibration(&cfg, &w, &stream, 8, 128).unwrap();
    println!(
        "calibrated {} sites in {:.2}s (8 windows x 128 tokens)",
        calib.sites.len(),
        t.ms() / 1e3
    );
    for kind in ["attn_in", "mlp_in"] {
        println!(
            "  S per layer ({kind}): {:?}",
            calib.s_series(kind, Format::Nvfp4, 512)
        );
    }
    println!();

    let methods: Vec<(&str, Option<Method>)> = vec![
        ("FP16", None),
        ("W4A8 + RTN", Some(Method::W4A8Rtn)),
        ("NVFP4 + RTN", Some(Method::Rtn { fmt: Format::Nvfp4 })),
        (
            "NVFP4 + Smooth",
            Some(Method::Smooth { fmt: Format::Nvfp4, alpha: 0.5 }),
        ),
        (
            "NVFP4 + QuaRot",
            Some(Method::QuaRot { fmt: Format::Nvfp4, seed: 0 }),
        ),
        ("FlatQuant", Some(Method::FlatQuant { fmt: Format::Nvfp4 })),
        ("Atom", Some(Method::Atom { outlier_channels: 128 })),
        (
            "ARCQuant",
            Some(Method::ArcQuant { fmt: Format::Nvfp4, max_s: Some(512) }),
        ),
    ];
    println!(
        "{:16} {:>8} {:>8} {:>8} {:>7} {:>8}",
        "method", "avg acc", "PPL", "MMLU", "avg S", "prep(s)"
    );
    for (label, m) in methods {
        let t = Timer::start();
        match ctx.eval_row(&model, m) {
            Ok(r) => println!(
                "{label:16} {:8.2} {:8.3} {:8.2} {:7} {:8.2}  [{:.0}s]",
                r.avg,
                r.ppl,
                r.mmlu,
                r.avg_s,
                r.prep_seconds,
                t.ms() / 1e3
            ),
            Err(e) => println!("{label:16} failed: {e}"),
        }
    }
}
