//! Regenerate every table and figure from the paper's evaluation section
//! (equivalent to `arcquant report --all`). Results also land as JSON in
//! artifacts/results/.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example paper_tables [--quick]

use arcquant::report::{figures, tables, Ctx, EvalBudget};
use arcquant::util::Timer;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let ctx = Ctx::new(
        "artifacts",
        if quick {
            EvalBudget::quick()
        } else {
            EvalBudget::default()
        },
    );

    println!("{}", figures::bounds_report());

    let all: Vec<(&str, &dyn Fn(&Ctx) -> Result<String, String>)> = vec![
        ("Table 1", &tables::table1),
        ("Table 2", &tables::table2),
        ("Table 3", &tables::table3),
        ("Table 4", &tables::table4),
        ("Table 5", &tables::table5),
        ("Table 6", &tables::table6),
        ("Table 7", &tables::table7),
        ("Table 8", &tables::table8),
        ("Figure 1", &figures::figure1),
        ("Figure 2", &figures::figure2),
        ("Figure 3", &figures::figure3),
        ("Figure 6", &figures::figure6),
        ("Figure 7", &figures::figure7),
        ("Figure 8", &figures::figure8),
        ("Figure 9", &figures::figure9),
    ];
    let total = Timer::start();
    for (name, f) in all {
        let t = Timer::start();
        match f(&ctx) {
            Ok(s) => println!("{s}  [{name} regenerated in {:.1}s]\n", t.ms() / 1e3),
            Err(e) => eprintln!("!! {name} failed: {e}\n"),
        }
    }
    println!("total: {:.1}s", total.ms() / 1e3);
}
