//! Quickstart: ARCQuant on a single linear layer, no artifacts needed.
//!
//! Builds an outlier-heavy activation matrix, quantizes it with NVFP4
//! RTN and with ARCQuant's augmented residual channels, and prints the
//! reconstruction errors plus the §3.4 worst-case bounds.
//!
//! Run: `cargo run --release --example quickstart`

use arcquant::formats::Format;
use arcquant::quant::{error, ArcQuantLinear, LayerPlan, PackedArcLinear};
use arcquant::tensor::{matmul_nt, Mat};
use arcquant::util::{stats, Prng};

fn main() {
    let mut rng = Prng::new(arcquant::DEFAULT_SEED);

    // Activations with a few dominant outlier channels — the LLM
    // phenomenon ARCQuant targets (paper Figure 2).
    let (n, k, m) = (64, 512, 128);
    let x = Mat::from_fn(n, k, |_, c| {
        let v = rng.normal();
        if c % 37 == 5 {
            v * 60.0
        } else {
            v
        }
    });
    let mut w = Mat::zeros(m, k);
    w.fill_random_normal(&mut rng, 0.3);
    let y_ref = matmul_nt(&x, &w);

    // --- NVFP4 RTN (no compensation) ---
    let rtn = ArcQuantLinear::prepare(&w, LayerPlan::rtn(k, Format::Nvfp4));
    let y_rtn = rtn.forward(&x);

    // --- ARCQuant: calibrate → reorder → top-S residual channels ---
    let plan = LayerPlan::from_calibration(&x.col_absmax(), Format::Nvfp4);
    println!(
        "calibration selected S = {} of {} channels (tau = 2^-3 M rule, 16-aligned)",
        plan.s, k
    );
    let arc = ArcQuantLinear::prepare(&w, plan.clone());
    let y_arc = arc.forward(&x);

    let e_rtn = stats::mse(&y_rtn.data, &y_ref.data);
    let e_arc = stats::mse(&y_arc.data, &y_ref.data);
    println!("reconstruction MSE   NVFP4+RTN: {e_rtn:.4}");
    println!(
        "reconstruction MSE   ARCQuant : {e_arc:.4}  ({:.1}x lower)",
        e_rtn / e_arc
    );
    println!(
        "GEMM shape: ({n}, {k}, {m}) -> augmented ({n}, {}, {m})",
        k + arc.s()
    );

    // --- packed execution: the same layer on real NVFP4 codes ---
    // (ExecPath::Packed — weights live as 4-bit codes + block scales,
    // activations are quantized straight to codes, and the GEMM decodes
    // 16-wide blocks on the fly. Same numerics, ~1/7 the weight memory.)
    let packed = PackedArcLinear::prepare(&w, plan).expect("aligned shapes");
    let y_packed = packed.forward(&x);
    let mut max_rel = 0f64;
    for (a, b) in y_packed.data.iter().zip(&y_arc.data) {
        let rel = ((a - b).abs() as f64) / (1.0 + b.abs() as f64);
        max_rel = max_rel.max(rel);
    }
    println!();
    println!("packed execution (codes end-to-end):");
    println!("  max deviation vs QDQ forward   = {max_rel:.2e}");
    println!(
        "  weight memory: packed {} B vs f32 {} B  ({:.1}x smaller)",
        packed.weight_bytes(),
        packed.qdq_equiv_bytes(),
        packed.qdq_equiv_bytes() as f64 / packed.weight_bytes() as f64
    );

    // --- §3.4 bounds ---
    println!();
    println!("3.4 worst-case bounds (per unit dynamic range M):");
    println!(
        "  B_mx  (MXFP8, E8M0 scales)       = {:.4} M",
        error::mxfp8_bound(1.0)
    );
    println!(
        "  B_arc (dual-stage NVFP4, E4M3)   = {:.4} M  (< B_mx)",
        error::arcquant_bound(1.0)
    );
    let sample: Vec<f32> = (0..2048).map(|_| rng.normal() * 4.0).collect();
    println!(
        "  empirical dual-stage rel err     = {:.5}",
        error::empirical_dual_stage_rel_err(&sample)
    );
    println!(
        "  empirical MXFP8 rel err          = {:.5}",
        error::empirical_single_stage_rel_err(&sample, Format::Mxfp8E4M3)
    );
}
