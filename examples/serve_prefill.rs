//! END-TO-END DRIVER: serve batched prefill requests through the full
//! stack — router → continuous batcher → PJRT executor running the
//! AOT-compiled JAX/Pallas artifacts — and report latency, throughput and
//! PPL per model variant (FP32 reference vs W4A4 ARCQuant vs NVFP4 RTN).
//!
//! This is the proof that all three layers compose: the L1 Pallas fused
//! quantization + augmented GEMM kernels, lowered inside the L2 JAX
//! transformer, executed from the L3 Rust coordinator with Python
//! nowhere on the request path. The run is recorded in EXPERIMENTS.md.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example serve_prefill

use arcquant::coordinator::{serve_workload, BatcherConfig, RouterConfig, ServeConfig, Variant};
use arcquant::report::{ctx::model_domain, Ctx, EvalBudget};

fn main() {
    let artifacts = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    let model = "llama8b-sim".to_string();
    let ctx = Ctx::new(&artifacts, EvalBudget::quick());
    let stream = match ctx.eval_stream(model_domain(&model)) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("cannot load eval corpus ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };

    let cfg = ServeConfig {
        artifacts,
        model,
        workload: vec![
            (Variant::Fp32, 8),
            (Variant::ArcQuant, 8),
            (Variant::Nvfp4Rtn, 8),
        ],
        req_len: 64,
        batcher: BatcherConfig::default(),
        router: RouterConfig::default(),
    };

    println!("== serve_prefill: end-to-end serving driver ==");
    println!("model {} | 24 requests (8 per variant) | req_len 64\n", cfg.model);
    match serve_workload(&cfg, &stream) {
        Ok(r) => {
            println!("platform: {} (PJRT)", r.platform);
            println!(
                "completed {}  rejected {}  wall {:.1}ms  p50 {:.1}ms  p90 {:.1}ms  p99 {:.1}ms",
                r.completed, r.rejected, r.wall_ms, r.p50_ms, r.p90_ms, r.p99_ms
            );
            println!("\nper-variant results:");
            println!(
                "  {:9} {:>4} {:>14} {:>9} {:>14}",
                "variant", "reqs", "mean exec (ms)", "PPL", "tok/s"
            );
            for (v, s) in &r.per_variant {
                println!(
                    "  {v:9} {:4} {:14.1} {:9.3} {:14.1}",
                    s.requests, s.mean_execute_ms, s.ppl, s.throughput_tok_s
                );
            }
            println!("\nstage breakdown (coordinator metrics → Fig. 8b analog):");
            for (stage, ms, share) in &r.stage_breakdown {
                println!("  {stage:22} {ms:10.1}ms {share:5.1}%");
            }
            // sanity: ARCQuant PPL must be close to FP32's
            if let (Some(fp), Some(arc)) =
                (r.per_variant.get("fp32"), r.per_variant.get("arcquant"))
            {
                let gap = arc.ppl / fp.ppl - 1.0;
                println!(
                    "\nARCQuant PPL gap vs FP32: {:+.2}% {}",
                    gap * 100.0,
                    if gap.abs() < 0.25 { "(OK)" } else { "(LARGE)" }
                );
            }
            println!("\nNOTE: on this CPU testbed the quantized variants run *slower*");
            println!("than FP32 — the QDQ simulation adds work; on Blackwell the NVFP4");
            println!("datapath is what accelerates. See costmodel + EXPERIMENTS.md.");
        }
        Err(e) => {
            eprintln!("serve failed: {e}");
            std::process::exit(1);
        }
    }
}
