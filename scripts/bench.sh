#!/usr/bin/env bash
# Repo-root bench runner: runs the GEMM + decode + HTTP-serving benches
# at pinned shapes/seeds (seeds are hardcoded in the bench sources) and
# rewrites BENCH_gemm_packed.json / BENCH_decode.json / BENCH_http.json
# in the repo root — the perf-trajectory files committed with each PR.
#
# bench_decode includes the KV-format series (decode throughput with f32
# vs NVFP4/MXFP4 K/V pages + admitted-sequence capacity at a fixed page
# budget); bench_http boots a real in-process HTTP server and drives it
# with the closed-loop loadgen at connection counts {1, 4, 16}; --smoke
# runs both at reduced shapes too, so CI exercises the quantized KV
# decode path and the socket serving path every push.
#
# Usage:
#   scripts/bench.sh            # full run, rewrites BENCH_*.json and runs
#                               # the trajectory gate against HEAD
#   scripts/bench.sh --smoke    # reduced shapes, no JSON rewrite; the
#                               # gate sanity-floors the GATE lines (CI)
#
# ARCQUANT_THREADS pins the worker pool; defaults to 4 here so trajectory
# numbers are comparable across differently-sized hosts.
set -euo pipefail
cd "$(dirname "$0")/.."

export ARCQUANT_THREADS="${ARCQUANT_THREADS:-4}"

# Smoke mode comes from the flag or an inherited ARCQUANT_BENCH_SMOKE —
# the benches honor the env var either way, so the final message must too.
SMOKE=0
if [[ "${1:-}" == "--smoke" ]]; then
  SMOKE=1
elif [[ -n "${ARCQUANT_BENCH_SMOKE:-}" && "${ARCQUANT_BENCH_SMOKE}" != "0" ]]; then
  SMOKE=1
fi

if [[ "$SMOKE" == "1" ]]; then
  export ARCQUANT_BENCH_SMOKE=1
  echo "# smoke mode: reduced shapes, BENCH_*.json left untouched"
fi

# Bench output is teed to a log so the trajectory gate can parse the
# stable `GATE key value` lines afterwards.
LOG="$(mktemp -t arcquant-bench.XXXXXX.log)"
trap 'rm -f "$LOG"' EXIT

cargo bench --bench bench_gemm_aug | tee -a "$LOG"
cargo bench --bench bench_decode | tee -a "$LOG"
cargo bench --bench bench_http | tee -a "$LOG"

# Trajectory gate (scripts/bench_gate.py):
#  * smoke: sanity-floor the GATE lines — catches kernel misroutes;
#  * full:  compare the freshly rewritten BENCH_*.json against the
#    committed copies (git show HEAD:...) and fail on regressions beyond
#    BENCH_GATE_TOLERANCE, but only when the committed provenance.source
#    matches the fresh one (cross-harness baselines are informational).
if [[ "$SMOKE" == "1" ]]; then
  python3 scripts/bench_gate.py --smoke "$LOG"
else
  echo "# rewrote BENCH_gemm_packed.json, BENCH_decode.json and BENCH_http.json"
  python3 scripts/bench_gate.py --full
fi
