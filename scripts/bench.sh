#!/usr/bin/env bash
# Repo-root bench runner: runs the GEMM + decode + HTTP-serving benches
# at pinned shapes/seeds (seeds are hardcoded in the bench sources) and
# rewrites BENCH_gemm_packed.json / BENCH_decode.json / BENCH_http.json
# in the repo root — the perf-trajectory files committed with each PR.
#
# bench_decode includes the KV-format series (decode throughput with f32
# vs NVFP4/MXFP4 K/V pages + admitted-sequence capacity at a fixed page
# budget); bench_http boots a real in-process HTTP server and drives it
# with the closed-loop loadgen at connection counts {1, 4, 16}; --smoke
# runs both at reduced shapes too, so CI exercises the quantized KV
# decode path and the socket serving path every push.
#
# Usage:
#   scripts/bench.sh            # full run, rewrites BENCH_*.json
#   scripts/bench.sh --smoke    # reduced shapes, no JSON rewrite (CI uses
#                               # this to catch kernel-routing panics)
#
# ARCQUANT_THREADS pins the worker pool; defaults to 4 here so trajectory
# numbers are comparable across differently-sized hosts.
set -euo pipefail
cd "$(dirname "$0")/.."

export ARCQUANT_THREADS="${ARCQUANT_THREADS:-4}"

# Smoke mode comes from the flag or an inherited ARCQUANT_BENCH_SMOKE —
# the benches honor the env var either way, so the final message must too.
SMOKE=0
if [[ "${1:-}" == "--smoke" ]]; then
  SMOKE=1
elif [[ -n "${ARCQUANT_BENCH_SMOKE:-}" && "${ARCQUANT_BENCH_SMOKE}" != "0" ]]; then
  SMOKE=1
fi

if [[ "$SMOKE" == "1" ]]; then
  export ARCQUANT_BENCH_SMOKE=1
  echo "# smoke mode: reduced shapes, BENCH_*.json left untouched"
fi

cargo bench --bench bench_gemm_aug
cargo bench --bench bench_decode
cargo bench --bench bench_http

if [[ "$SMOKE" == "0" ]]; then
  echo "# rewrote BENCH_gemm_packed.json, BENCH_decode.json and BENCH_http.json"
fi
